"""Triton-style batched inference server backed by the HPS.

Request flow (paper Figure 2, red path): requests queue up, a batcher
drains up to ``max_batch`` of them, the HPS resolves embeddings (L1 device
cache -> L2 VDB -> L3 PDB), and the jitted dense net computes predictions.
``deploy_from_training`` exports a trained model into the PDB — the
offline-training deployment path; online updates arrive via the bus.

The serve loop is a STREAM-FED pipeline (``engine="stream"``, the
default): drained request groups feed the dense network directly from
``HPS.lookup_stream`` with no caller-thread materialization in between —
while query *i-1*'s prediction materializes, query *i*'s pooled
embeddings and dense net are computing on device and query *i+1*'s index
probes (and their remote L2/L3 miss fetches) run on the HPS host
workers. The only host sync point per query is the prediction itself.
Predictions are bit-identical to the unpipelined path: the per-plan
payload snapshots make the lookup machinery order-independent, and the
dense net is the same jitted function either way. Two reference engines
remain selectable: ``"sync"`` (drain -> one blocking ``predict`` per
group — the old loop, where XLA async dispatch still overlaps device
work behind the host) and ``"stage_sync"`` (every device stage blocked
before the next host stage — the no-overlap baseline the benchmarks
measure against).

ADMISSION CONTROL (the submit path's QoS layer, off by default so the
bare server behaves exactly as before): a server constructed — or
configured via ``set_admission`` — with ``queue_depth`` and/or
``slo_ms`` becomes an admission-controlled endpoint:

- **Bounded queue + graceful shedding.** ``submit`` beyond
  ``queue_depth`` outstanding requests — or after ``close()`` — never
  enqueues: the caller's handle receives a typed
  :class:`ServerOverloaded` IMMEDIATELY (counted in
  ``requests_shed``), so overload degrades into fast typed rejections
  instead of unbounded queueing or hung callers.
- **Deadline-aware dynamic batching.** With ``slo_ms`` declared, the
  batcher sizes each request group from the OLDEST queued request's
  remaining slack (:func:`deadline_batch_target`: grow toward
  ``max_batch`` while the predicted completion fits the SLO, cut early
  when slack is short), and a request whose deadline already passed at
  drain time is shed (``requests_expired``) rather than served late —
  serving it would burn capacity that fresher requests still have a
  chance of using. Delivered requests that still missed the SLO count
  in ``slo_violations``.
- **close() never strands a handle.** ``close()`` refuses new
  admissions, lets the serve loop finish in-flight groups, then drains
  every still-queued handle with the typed rejection.

The serve loop also drives update propagation (no bare timer threads):
between pipeline stages it polls the message bus into L2/L3, marks the
touched L1 rows dirty, and drains one bounded hotness-ordered refresh
chunk per tick — so refresh IO interleaves with serving instead of
stopping the world, and a periodic ``refresh_poll_s`` full-mark sweeps
rows whose updates arrived out of band.

``MultiModelServer`` fronts SEVERAL models from one storage backend —
per-model serve loops and L1 caches over a shared VolatileDB
(model-namespaced keys), a shared PersistentDB (model-namespaced tables)
and a shared message bus (model-scoped topics): the ensemble deployment
unit of the GPU-specialized inference parameter server (arXiv
2210.08804), reconstructed by ``launch.serve.build_server_from_config``
from one ps.json bundle.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from typing import (Callable, Dict, List, Mapping, NamedTuple, Optional,
                    Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig, RecsysConfig
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB
from repro.loadgen.metrics import LatencyHistogram

ENGINES = ("stream", "sync", "stage_sync")


class ServerOverloaded(Exception):
    """Typed rejection delivered to a request handle instead of a
    prediction: the admission queue was full, the request's deadline
    expired before it could be served, or the server was closed.
    Callers distinguish it from a prediction (and from a failed-group
    exception) by type — a shed is an expected overload outcome, not a
    serving bug."""


def deadline_batch_target(oldest_age_ms: float, slo_ms: float,
                          max_batch: int,
                          service_ms_per_row: Optional[float]) -> int:
    """Rows a forming request group may grow to before its OLDEST
    member risks the latency SLO.

    The decision never exceeds the declared budget: the returned
    ``target`` satisfies ``oldest_age_ms + target * service_ms_per_row
    <= slo_ms`` whenever a service estimate exists — or is the floor
    ``1`` (the oldest request always ships; a sub-SLO completion is
    impossible, so ship the smallest group now rather than hold it).
    With plenty of slack the target grows toward ``max_batch``
    (coalescing amortizes the per-group overhead); with no estimate yet
    (cold server) the full ``max_batch`` is allowed until the deadline
    itself has passed.
    """
    if oldest_age_ms >= slo_ms:
        return 1
    if service_ms_per_row is None or service_ms_per_row <= 0:
        return max_batch
    slack = slo_ms - oldest_age_ms
    return max(1, min(max_batch, int(slack / service_ms_per_row)))


class _Req(NamedTuple):
    """One queued request: arrays, the caller's handle, and the
    admission timestamp the SLO accounting measures from."""
    dense: np.ndarray
    cat: np.ndarray
    done: "queue.Queue"
    t_enq: float


def deploy_from_training(model, params: Dict, pdb: PersistentDB,
                         model_name: str) -> None:
    """Export trained embedding tables into the PDB (ground truth copy).

    EVERY collection exports: the deep tables, the dim-1 ``*_wide``
    twins of wide models (wdl/deepfm), and each extra N-group
    collection's tables — so the serving side can stand up one HPS per
    dim class from the PDB alone.
    """
    from repro.models.recsys.model import logical_tables
    for key, coll in model.collections().items():
        for name, full in logical_tables(coll, params[key]).items():
            pdb.create_table(model_name, name, full.shape[0],
                             full.shape[1], initial=full)
    pdb.flush()


class InferenceServer:

    # Checked by `python -m repro.analysis`: serving counters and the
    # latency histogram are written by the serve-loop thread and read by
    # stats/benchmark callers, so they live behind _stats_lock; the
    # admission gate (closed flag + shed counter) is touched from every
    # SUBMITTING thread, so it has its own lock — the two are never
    # nested.
    _GUARDED_BY = {
        "updates_applied": "_stats_lock",
        "rows_refreshed": "_stats_lock",
        "latency_hist": "_stats_lock",
        "requests_delivered": "_stats_lock",
        "requests_expired": "_stats_lock",
        "slo_violations": "_stats_lock",
        "_service_ms_per_row": "_stats_lock",
        "_closed": "_admit_lock",
        "requests_shed": "_admit_lock",
    }

    def __init__(self, model, dense_params: Dict, hps: HPS, *,
                 max_batch: int = 1024, needs_wide: bool = False,
                 wide_hps: Optional[HPS] = None,
                 extra_hps: Optional[Dict[str, HPS]] = None,
                 hotness: Optional[Sequence[int]] = None,
                 refresh_budget: int = 512,
                 refresh_poll_s: Optional[float] = None,
                 engine: str = "stream",
                 queue_depth: Optional[int] = None,
                 slo_ms: Optional[float] = None,
                 deadline_batching: bool = True):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        self.model = model
        self.hps = hps
        self.wide_hps = wide_hps
        #: one HPS per extra N-group embedding collection, keyed by group
        #: name — each reads its own cat column span (see ``_cols``)
        self.extra_hps: Dict[str, HPS] = dict(extra_hps or {})
        #: cat column span per embedding group. Populated only for
        #: N-group models (extras present); single-group servers keep it
        #: empty and every lookup sees the full cat block, exactly as
        #: before.
        self._cols: Dict[str, Tuple[int, int]] = \
            dict(model.group_columns()) if self.extra_hps else {}
        #: optional per-table hotness forwarded to HPS.lookup (validated
        #: there against the request shape); covers ALL cat columns in
        #: group order and is sliced per group alongside cat
        self.hotness = list(hotness) if hotness is not None else None
        self.dense_params = dense_params
        self.max_batch = max_batch
        self.engine = engine
        #: rows re-pulled per refresh chunk between drained batches
        self.refresh_budget = refresh_budget
        #: period of the full-mark sweep (None = only bus-marked rows)
        self.refresh_poll_s = refresh_poll_s
        #: admission policy (None = unbounded / no SLO — legacy behavior)
        self.queue_depth = queue_depth
        self.slo_ms = slo_ms
        self.deadline_batching = deadline_batching
        self._stats_lock = threading.Lock()
        self.updates_applied = 0
        self.rows_refreshed = 0
        #: bounded-memory per-group latency store (mergeable log-bucketed
        #: histogram — a soak test costs the same KiBs as a smoke run)
        self.latency_hist = LatencyHistogram()
        self.requests_delivered = 0
        self.requests_expired = 0
        self.slo_violations = 0
        #: EWMA of observed service time per delivered row, feeding the
        #: deadline batcher's cut decision (None until the first group)
        self._service_ms_per_row: Optional[float] = None
        self._admit_lock = threading.Lock()
        self._closed = False
        self.requests_shed = 0
        self._last_poll = time.monotonic()
        if self.extra_hps:
            self._predict = jax.jit(
                lambda p, d, e, w, x: model.apply_dense(p, d, e, w,
                                                        extras=x))
            self._predict_nowide = jax.jit(
                lambda p, d, e, x: model.apply_dense(p, d, e, None,
                                                     extras=x))
        else:
            self._predict = jax.jit(
                lambda p, d, e, w: model.apply_dense(p, d, e, w))
            self._predict_nowide = jax.jit(
                lambda p, d, e: model.apply_dense(p, d, e, None))
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth or 0)
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        #: control-plane hook run at the end of every ``_refresh_tick``
        #: (the ensemble budget rebalancer registers itself here); must
        #: be cheap or internally rate-limited — it runs on the serve
        #: loop between pipeline stages
        self.on_tick: Optional[Callable[[], None]] = None

    def set_admission(self, *, queue_depth: Optional[int] = None,
                      slo_ms: Optional[float] = None,
                      deadline_batching: bool = True) -> None:
        """Declare (or replace) the admission policy on an idle server —
        the request queue is swapped for one with the new bound, so this
        must run before ``start()`` / concurrent submits. Requests
        already queued carry over; any overflow beyond the new bound is
        shed with the typed rejection."""
        if self._worker is not None:
            raise RuntimeError("set_admission() requires a stopped "
                               "server: call it before start()")
        self.queue_depth = queue_depth
        self.slo_ms = slo_ms
        self.deadline_batching = deadline_batching
        newq: queue.Queue = queue.Queue(maxsize=queue_depth or 0)
        shed = 0
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                break
            try:
                newq.put_nowait(req)
            except queue.Full:
                self._put_rejection(req, "queue bound shrank")
                shed += 1
        self._q = newq
        if shed:
            with self._admit_lock:
                self.requests_shed += shed

    def _record_latency(self, t0: float, rows: int = 0) -> None:
        ms = (time.perf_counter() - t0) * 1e3
        with self._stats_lock:
            self.latency_hist.record(ms)
            if rows > 0:        # feed the deadline batcher's estimate
                obs = ms / rows
                self._service_ms_per_row = obs \
                    if self._service_ms_per_row is None \
                    else 0.8 * self._service_ms_per_row + 0.2 * obs

    # -- synchronous path ---------------------------------------------------------

    def _group_cat(self, cat: np.ndarray, key: str) -> np.ndarray:
        """Column slice of a request's cat block for one embedding group
        (identity for single-group servers)."""
        if not self._cols:
            return cat
        lo, hi = self._cols[key]
        return cat[:, lo:hi, :]

    def _group_hot(self, key: str) -> Optional[List[int]]:
        if not self._cols or self.hotness is None:
            return self.hotness
        lo, hi = self._cols[key]
        return self.hotness[lo:hi]

    def _dense_forward(self, dense: np.ndarray, emb: jax.Array,
                       wide: Optional[jax.Array],
                       extras: Optional[Dict[str, jax.Array]] = None
                       ) -> jax.Array:
        """The one jitted dense-net dispatch + host-side sigmoid — shared
        by every engine so outputs are bit-identical across them."""
        d = jnp.asarray(dense)
        if self.extra_hps:
            if wide is not None:
                out = self._predict(self.dense_params, d, emb, wide,
                                    extras or {})
            else:
                out = self._predict_nowide(self.dense_params, d, emb,
                                           extras or {})
        elif wide is not None:
            out = self._predict(self.dense_params, d, emb, wide)
        else:
            out = self._predict_nowide(self.dense_params, d, emb)
        return jax.nn.sigmoid(out)

    def predict(self, dense: np.ndarray, cat: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        dcat = self._group_cat(cat, "embedding")
        dhot = self._group_hot("embedding")
        emb = self.hps.lookup(dcat, dhot,
                              pipelined=len(self.hps.tables) > 1)
        wide = None
        if self.wide_hps is not None:       # wide twins share the deep
            wide = self.wide_hps.lookup(    # group's cat columns
                dcat, dhot,
                pipelined=len(self.wide_hps.tables) > 1)
        extras = {
            name: hps.lookup(self._group_cat(cat, f"embedding@{name}"),
                             self._group_hot(f"embedding@{name}"),
                             pipelined=len(hps.tables) > 1)
            for name, hps in self.extra_hps.items()}
        out = np.asarray(self._dense_forward(dense, emb, wide, extras))
        self._record_latency(t0, rows=dense.shape[0])
        return out

    def _predict_stage_sync(self, dense: np.ndarray,
                            cat: np.ndarray) -> np.ndarray:
        """The no-overlap reference: every embedding device stage blocks
        before the next host stage, the dense net blocks before the
        sigmoid — nothing is left to XLA's async dispatch."""
        t0 = time.perf_counter()
        dcat = self._group_cat(cat, "embedding")
        dhot = self._group_hot("embedding")
        emb = self.hps.lookup_stage_sync(dcat, dhot)
        wide = None
        if self.wide_hps is not None:
            wide = self.wide_hps.lookup_stage_sync(dcat, dhot)
        extras = {
            name: hps.lookup_stage_sync(
                self._group_cat(cat, f"embedding@{name}"),
                self._group_hot(f"embedding@{name}"))
            for name, hps in self.extra_hps.items()}
        d = jnp.asarray(dense)
        if self.extra_hps:
            if wide is not None:
                out = self._predict(self.dense_params, d, emb, wide,
                                    extras)
            else:
                out = self._predict_nowide(self.dense_params, d, emb,
                                           extras)
        elif wide is not None:
            out = self._predict(self.dense_params, d, emb, wide)
        else:
            out = self._predict_nowide(self.dense_params, d, emb)
        out = np.asarray(jax.nn.sigmoid(jax.block_until_ready(out)))
        self._record_latency(t0, rows=dense.shape[0])
        return out

    # -- refresh scheduling (runs on the serve loop, between batches) -------------

    def _refresh_tick(self) -> None:
        """One serving-loop tick of update propagation: bus -> L2/L3 (+
        dirty marks), a periodic full-mark sweep, and ONE bounded
        hotness-ordered refresh chunk — never a stop-the-world re-pull.
        Covers every HPS this server reads from (deep AND wide).

        Safe to interleave anywhere between pipeline stages: in-flight
        lookup plans carry their own lock-consistent payload snapshots,
        so a refresh scatter landing between a query's probe and its
        device stage can never tear that query's view."""
        sweep = False
        if self.refresh_poll_s is not None:
            now = time.monotonic()
            if now - self._last_poll >= self.refresh_poll_s:
                self._last_poll = now
                sweep = True
        applied = refreshed = 0            # the bus/refresh IO runs
        for hps in (self.hps, self.wide_hps,    # unlocked; counters
                    *self.extra_hps.values()):  # update in one step below
            if hps is None:
                continue
            if hps.consumer is not None:
                applied += hps.apply_updates()
            if sweep:
                hps.schedule_refresh()
            if hps.refresh_backlog():
                refreshed += hps.refresh_step(self.refresh_budget)
        if applied or refreshed:
            with self._stats_lock:
                self.updates_applied += applied
                self.rows_refreshed += refreshed
        if self.on_tick is not None:
            self.on_tick()

    # -- queued/batched path --------------------------------------------------------

    def submit(self, dense: np.ndarray, cat: np.ndarray) -> "queue.Queue":
        """Queue a request; the returned handle's ``get()`` yields the
        prediction rows (or the exception that failed its batch).

        With admission control on, a full queue or a closed server
        delivers a typed :class:`ServerOverloaded` to the handle
        IMMEDIATELY — the caller never blocks on a request the server
        already decided not to serve."""
        done: queue.Queue = queue.Queue(maxsize=1)
        req = _Req(dense, cat, done, time.perf_counter())
        rejection = None
        with self._admit_lock:
            if self._closed:
                self.requests_shed += 1
                rejection = "server closed"
            else:
                try:
                    self._q.put_nowait(req)
                except queue.Full:
                    self.requests_shed += 1
                    rejection = (f"admission queue full "
                                 f"(depth {self.queue_depth})")
        if rejection is not None:
            self._put_rejection(req, rejection)
        return done

    @staticmethod
    def _put_rejection(req: _Req, why: str) -> None:
        try:
            req.done.put_nowait(ServerOverloaded(why))
        except queue.Full:
            pass

    def _expired(self, req: _Req) -> bool:
        """Deadline shedding applies only with an SLO declared AND
        deadline batching on — the fixed-coalescing reference arm serves
        everything it admitted, however late."""
        if self.slo_ms is None or not self.deadline_batching:
            return False
        return (time.perf_counter() - req.t_enq) * 1e3 >= self.slo_ms

    def _batch_target(self, first: _Req) -> int:
        if self.slo_ms is None or not self.deadline_batching:
            return self.max_batch
        age_ms = (time.perf_counter() - first.t_enq) * 1e3
        with self._stats_lock:
            est = self._service_ms_per_row
        return deadline_batch_target(age_ms, self.slo_ms,
                                     self.max_batch, est)

    def _coalesce(self, first
                  ) -> Optional[Tuple[list, np.ndarray, np.ndarray]]:
        """Drain the queue behind ``first`` into one coalesced request
        group (the batcher of the paper's Figure 2 — one group is one
        device batch), bounded by ``max_batch`` rows or, with an SLO
        declared, by the oldest request's remaining slack
        (:func:`deadline_batch_target`; the group may overshoot the
        target by at most the last drained request, since a drained
        request is never re-queued). An expired head is shed with the
        typed rejection instead of served late. Requests that cannot be
        concatenated (mismatched widths) get the error delivered to
        their handles here and ``None`` comes back — the serve loop must
        keep running."""
        while self._expired(first):
            self._put_rejection(first, f"deadline expired "
                                       f"(slo {self.slo_ms}ms)")
            with self._stats_lock:
                self.requests_expired += 1
            try:
                first = self._q.get_nowait()
            except queue.Empty:
                return None
        reqs = [first]
        rows = first.dense.shape[0]
        target = self._batch_target(first)
        while rows < target:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            reqs.append(nxt)
            rows += nxt.dense.shape[0]
        try:
            dense = np.concatenate([r.dense for r in reqs])
            cat = np.concatenate([r.cat for r in reqs])
        except Exception as exc:
            self._deliver_error(reqs, exc)
            return None
        return reqs, dense, cat

    def _deliver(self, reqs: list, preds: np.ndarray) -> None:
        off = 0
        now = time.perf_counter()
        delivered = violations = 0
        for r in reqs:
            n = r.dense.shape[0]
            r.done.put(preds[off:off + n])
            off += n
            delivered += 1
            if self.slo_ms is not None and \
                    (now - r.t_enq) * 1e3 > self.slo_ms:
                violations += 1
        with self._stats_lock:
            self.requests_delivered += delivered
            self.slo_violations += violations

    @staticmethod
    def _deliver_error(reqs: list, exc: BaseException) -> None:
        for r in reqs:
            try:
                r.done.put_nowait(exc)
            except queue.Full:
                pass

    # -- the stream-fed pipeline (engine="stream") ----------------------------------

    def _serve_burst_stream(self, first) -> None:
        """Pipeline one burst of requests end-to-end: request groups are
        admitted into ``HPS.lookup_stream`` (host probes + remote
        fetches run ahead on the HPS workers), each yielded DEVICE
        embedding block feeds the jitted dense net immediately, and
        predictions materialize ONE GROUP BEHIND the dense dispatch —
        group *i+1* probes the host index while group *i*'s payload
        scatters + dense net run and group *i-1*'s prediction leaves for
        its callers. ``_refresh_tick`` interleaves between stages. The
        burst ends when the request queue goes empty; the pipeline then
        drains in order.
        """
        fifo: deque = deque()   # (reqs, dense, t0) in admission order
        head = [first]

        def cats():
            while True:
                if head:        # ALWAYS serve the already-dequeued
                    nxt = head.pop()    # request, even under stop()
                elif self._stop.is_set():
                    return      # stop only gates NEW admissions
                else:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        return
                group = self._coalesce(nxt)
                if group is None:           # un-concatenatable: errors
                    continue                # already delivered
                reqs, dense, cat = group
                if dense.shape[0] == 0:     # degenerate empty group
                    self._deliver(reqs, np.zeros((0,), np.float32))
                    continue
                fifo.append((reqs, dense, time.perf_counter()))
                yield cat

        def group_src(src, key):
            """Wrap one tee branch with the group's column slice (the
            identity for single-group servers)."""
            if not self._cols:
                return src
            lo, hi = self._cols[key]
            return (c[:, lo:hi, :] for c in src)

        extra_names = list(self.extra_hps)
        n_wide = 1 if self.wide_hps is not None else 0
        srcs = iter(itertools.tee(cats(), 1 + n_wide + len(extra_names)))
        streams = [self.hps.lookup_stream(
            group_src(next(srcs), "embedding"),
            self._group_hot("embedding"), materialize=False)]
        if self.wide_hps is not None:       # wide twins read the deep
            streams.append(self.wide_hps.lookup_stream(  # group's columns
                group_src(next(srcs), "embedding"),
                self._group_hot("embedding"), materialize=False))
        for name in extra_names:
            key = f"embedding@{name}"
            streams.append(self.extra_hps[name].lookup_stream(
                group_src(next(srcs), key), self._group_hot(key),
                materialize=False))

        in_flight: deque = deque()          # (reqs, t0, device preds)
        current = None                      # group between fifo/in_flight
        try:
            for vals in zip(*streams):
                emb = vals[0]
                wide = vals[1] if n_wide else None
                extras = dict(zip(extra_names, vals[1 + n_wide:]))
                current = fifo.popleft()    # (reqs, dense, t0)
                out = self._dense_forward(current[1], emb, wide, extras)
                in_flight.append((current[0], current[2], out))
                current = None
                self._refresh_tick()        # between pipeline stages
                if len(in_flight) > 1:      # materialize one behind
                    self._materialize(in_flight.popleft())
            while in_flight:
                self._materialize(in_flight.popleft())
        except Exception as exc:            # a poisoned group kills the
            if current is not None:         # burst: surface the error to
                self._deliver_error(current[0], exc)  # EVERY undelivered
            for reqs, _, _ in in_flight:    # handle (the failing group's
                self._deliver_error(reqs, exc)   # own included) instead
            for reqs, _, _ in fifo:         # of hanging callers
                self._deliver_error(reqs, exc)

    def _materialize(self, item) -> None:
        reqs, t0, pred = item
        try:
            preds = np.asarray(pred)        # the one sync point per group
        except Exception as exc:            # deferred device error: this
            self._deliver_error(reqs, exc)  # group's handles first, the
            raise                           # burst handler does the rest
        self._record_latency(t0, rows=len(preds))
        self._deliver(reqs, preds)

    # -- serve loop -----------------------------------------------------------------

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                self._refresh_tick()     # idle: drain the refresh backlog
                continue
            if self.engine == "stream":
                self._serve_burst_stream(first)
                continue
            group = self._coalesce(first)
            if group is None:               # errors already delivered
                self._refresh_tick()
                continue
            reqs, dense, cat = group
            try:
                if self.engine == "stage_sync":
                    preds = self._predict_stage_sync(dense, cat)
                else:
                    preds = self.predict(dense, cat)
            except Exception as exc:
                self._deliver_error(reqs, exc)
            else:
                self._deliver(reqs, preds)
            self._refresh_tick()         # interleave refresh with serving

    def start(self):
        with self._admit_lock:
            if self._closed:
                raise RuntimeError("server is closed")
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    def stop(self):
        self._stop.set()
        if self._worker:
            self._worker.join()
            self._worker = None
        self._stop.clear()

    def close(self):
        """Terminal shutdown that never strands a caller: refuse new
        admissions (submits from here on get the typed rejection), let
        the serve loop finish the groups it already pulled, then deliver
        :class:`ServerOverloaded` to every handle still in the queue —
        after ``close()`` returns, every handle ever issued holds a
        prediction or an exception."""
        with self._admit_lock:
            self._closed = True
        self.stop()
        shed = 0
        while True:         # no racing producers: _closed gates submit
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                break
            self._put_rejection(req, "server closed")
            shed += 1
        if shed:
            with self._admit_lock:
                self.requests_shed += shed

    def latency_percentiles(self) -> Dict[str, float]:
        with self._stats_lock:
            hist = self.latency_hist.snapshot()
        if hist.count == 0:
            return {}
        s = hist.summary()
        return {"p50": s["p50"], "p95": s["p95"], "p99": s["p99"],
                "p999": s["p999"], "mean": s["mean"]}

    def reset_latencies(self) -> None:
        """Drop accumulated latency samples (benchmark warmup reset)."""
        with self._stats_lock:
            self.latency_hist.reset()

    def reset_serving_stats(self) -> None:
        """Zero latency samples AND admission counters — the load-test
        harness calls this between warmup and the measured phase."""
        with self._stats_lock:
            self.latency_hist.reset()
            self.requests_delivered = 0
            self.requests_expired = 0
            self.slo_violations = 0
        with self._admit_lock:
            self.requests_shed = 0

    def update_versions(self) -> Dict[str, int]:
        """Highest online-update version applied per table, across every
        HPS this server reads from — the serving half of the freshness
        contract (``repro.online.UpdatePublisher`` stamps the versions;
        a freshness probe polls this until the published version lands)."""
        out: Dict[str, int] = {}
        for hps in (self.hps, self.wide_hps, *self.extra_hps.values()):
            if hps is None or hps.consumer is None:
                continue
            out.update(hps.consumer.last_versions)
        return out

    def counters(self) -> Dict[str, int]:
        """Lock-consistent snapshot of the serving counters."""
        with self._stats_lock:
            out = {"updates_applied": self.updates_applied,
                   "rows_refreshed": self.rows_refreshed,
                   "groups_served": self.latency_hist.count,
                   "requests_delivered": self.requests_delivered,
                   "requests_expired": self.requests_expired,
                   "slo_violations": self.slo_violations}
        with self._admit_lock:
            out["requests_shed"] = self.requests_shed
        return out


class MultiModelServer:
    """Several models served from ONE parameter-server process.

    Each member keeps its own serve loop, dense net and L1 device caches
    (embedding working sets must not thrash each other); the storage
    levels below are SHARED — one VolatileDB (keys namespaced
    ``model/table`` by the HPS), one PersistentDB (tables namespaced per
    model on disk) and one message bus (topics scoped
    ``hps.<model>.<table>``) — so adding a model to a deployment adds
    L1 state only, and one model's online updates can never touch
    another's tables at any level. Predictions are bit-exact with
    per-model in-process servers: sharing storage shares bytes, not
    values.

    With ``cache_budget`` AND ``rebalance_interval_s`` set, the shared
    L1 row budget is periodically RE-SPLIT from observed per-model miss
    pressure (the deploy-time split is static declared hotness —
    ``api.hotness_cache_capacities``): each member's serve loop tick
    calls into the rebalancer, which at most once per interval re-splits
    the budget proportional to each model's L1 miss delta since the last
    split and resizes the member caches (hottest rows retained). Opt-in
    because a resize recompiles the pooled gather for the new payload
    shape — leave it off when the hot-path sanitizer's zero-recompile
    contract matters more than cache efficiency.

    Admission control is per member: declare each model's SLO and queue
    bound via ``server[name].set_admission(...)`` — the members' shed /
    violation counters surface in ``stats()``.
    """

    # Checked by `python -m repro.analysis`: rebalance bookkeeping is
    # touched from every member's serve loop, so it lives behind the
    # rebalance lock (acquired non-blocking — serving never waits on it).
    _GUARDED_BY = {
        "_last_counts": "_rebalance_lock",
        "_last_rebalance": "_rebalance_lock",
        "rebalances": "_rebalance_lock",
    }

    def __init__(self, servers: Mapping[str, InferenceServer], *,
                 vdb: Optional[VolatileDB] = None,
                 pdb: Optional[PersistentDB] = None,
                 bus: Optional[MessageBus] = None,
                 cache_budget: Optional[int] = None,
                 rebalance_interval_s: Optional[float] = None,
                 rebalance_floor: int = 64):
        if not servers:
            raise ValueError("MultiModelServer needs at least one model")
        self.servers: Dict[str, InferenceServer] = dict(servers)
        self.vdb = vdb
        self.pdb = pdb
        self.bus = bus
        self.cache_budget = cache_budget
        self.rebalance_interval_s = rebalance_interval_s
        self.rebalance_floor = rebalance_floor
        self.rebalances = 0
        self._rebalance_lock = threading.Lock()
        self._last_counts: Dict[str, Tuple[int, int]] = {}
        self._last_rebalance = time.monotonic()
        if cache_budget is not None and rebalance_interval_s is not None:
            for s in self.servers.values():
                s.on_tick = self._rebalance_tick

    @property
    def models(self) -> List[str]:
        return list(self.servers)

    def __getitem__(self, model: str) -> InferenceServer:
        return self._server(model)

    def _server(self, model: str) -> InferenceServer:
        try:
            return self.servers[model]
        except KeyError:
            raise KeyError(f"unknown model {model!r}; serving "
                           f"{self.models}") from None

    def predict(self, model: str, dense: np.ndarray,
                cat: np.ndarray) -> np.ndarray:
        return self._server(model).predict(dense, cat)

    def submit(self, model: str, dense: np.ndarray,
               cat: np.ndarray) -> "queue.Queue":
        return self._server(model).submit(dense, cat)

    # -- observed-hit-rate budget rebalance ----------------------------------

    def _rebalance_tick(self) -> None:
        """Serve-loop hook: re-split the shared L1 budget at most once
        per ``rebalance_interval_s``. Non-blocking — if another member's
        loop is mid-rebalance, this tick just returns."""
        if not self._rebalance_lock.acquire(blocking=False):
            return
        try:  # the non-blocking acquire above holds the lock through here
            now = time.monotonic()
            # lock-ok: LOCK001 inside acquire(blocking=False)/finally-release — held, just not a with-block
            if now - self._last_rebalance < self.rebalance_interval_s:
                return
            # lock-ok: LOCK001 inside acquire(blocking=False)/finally-release — held, just not a with-block
            self._last_rebalance = now
            # lock-ok: LOCK004 inside acquire(blocking=False)/finally-release — held, just not a with-block
            self._rebalance_locked()
        finally:
            self._rebalance_lock.release()

    def rebalance_now(self) -> Dict[str, int]:
        """Force one budget re-split immediately (tests / operators);
        returns the per-model capacities now in effect."""
        with self._rebalance_lock:
            self._last_rebalance = time.monotonic()
            self._rebalance_locked()
        return {name: s.hps.cache_capacity
                for name, s in self.servers.items()}

    def _rebalance_locked(self) -> None:
        """Split ``cache_budget`` proportional to each model's observed
        L1 miss delta since the last split (+1 smoothing so an idle
        member keeps a foothold), floored so a cold member still serves,
        and resize members whose share moved more than 10% — small
        drifts are not worth the resize's gather recompile."""
        demand: Dict[str, int] = {}
        for name, s in self.servers.items():
            hits = misses = 0
            for c in s.hps.caches.values():
                cnt = c.counters()
                hits += cnt["hits"]
                misses += cnt["misses"]
            _, pm = self._last_counts.get(name, (0, 0))
            self._last_counts[name] = (hits, misses)
            demand[name] = (misses - pm) + 1
        total = sum(demand.values())
        moved = 0
        for name, d in demand.items():
            s = self.servers[name]
            floor = max(self.rebalance_floor, s.hps.cache_shards)
            cap = max(floor, int(round(self.cache_budget * d / total)))
            cur = s.hps.cache_capacity
            if abs(cap - cur) <= max(1, int(0.1 * cur)):
                continue
            s.hps.resize_caches(cap)
            if s.wide_hps is not None:
                s.wide_hps.resize_caches(cap)
            for ehps in s.extra_hps.values():
                ehps.resize_caches(cap)
            moved += 1
        if moved:
            self.rebalances += 1

    def start(self):
        for s in self.servers.values():
            s.start()

    def stop(self):
        for s in self.servers.values():
            s.stop()

    def close(self):
        """Close every member: refuse new work, finish in-flight groups,
        reject every still-queued handle — no caller blocks forever."""
        for s in self.servers.values():
            s.close()

    def stats(self) -> Dict[str, Dict]:
        """Per-model serving picture: L1/L2/L3 + refresh + latency +
        admission (shed / expired / SLO-violation counts)."""
        out = {}
        for name, s in self.servers.items():
            c = s.counters()
            out[name] = {"hps": s.hps.stats(),
                         "cache_capacity": s.hps.cache_capacity,
                         "latency_ms": s.latency_percentiles(),
                         "updates_applied": c["updates_applied"],
                         "rows_refreshed": c["rows_refreshed"],
                         "requests_delivered": c["requests_delivered"],
                         "requests_shed": c["requests_shed"],
                         "requests_expired": c["requests_expired"],
                         "slo_violations": c["slo_violations"]}
        return out

    def rebalance_stats(self) -> Dict:
        """Budget-rebalancer picture: splits performed + current split."""
        with self._rebalance_lock:
            n = self.rebalances
        return {"rebalances": n, "cache_budget": self.cache_budget,
                "capacities": {name: s.hps.cache_capacity
                               for name, s in self.servers.items()}}
