"""Triton-style batched inference server backed by the HPS.

Request flow (paper Figure 2, red path): requests queue up, a batcher
drains up to ``max_batch`` of them, the HPS resolves embeddings (L1 device
cache -> L2 VDB -> L3 PDB), and the jitted dense net computes predictions.
``deploy_from_training`` exports a trained model into the PDB — the
offline-training deployment path; online updates arrive via the bus.

The embedding path is fully batched end-to-end: the coalesced request
batch goes through ``HPS.lookup`` as ONE vectorized resolve (per-table
misses coalesce into one fetch + one payload scatter; the stacked pooled
``[B, T, D]`` comes back in a single jitted device call) and feeds the
jitted dense net without bouncing through host memory — so batching
requests amortizes both the host index work and the device dispatches,
which is what produces the paper's batch-dependent speedup curve. With
two or more tables the lookup runs pipelined: the HPS host worker probes
table *t+1* while table *t*'s scatter is in flight.

The serve loop also drives update propagation (no bare timer threads):
between drained batches it polls the message bus into L2/L3, marks the
touched L1 rows dirty, and drains one bounded hotness-ordered refresh
chunk per tick — so refresh IO interleaves with serving instead of
stopping the world, and a periodic ``refresh_poll_s`` full-mark sweeps
rows whose updates arrived out of band.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig, RecsysConfig
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB


def deploy_from_training(model, params: Dict, pdb: PersistentDB,
                         model_name: str) -> None:
    """Export trained embedding tables into the PDB (ground truth copy).

    Wide models (wdl/deepfm) export BOTH table sets: the deep tables and
    their dim-1 ``*_wide`` twins, so the serving side can stand up the
    second HPS the wide branch needs.
    """
    from repro.models.recsys.model import logical_tables
    for name, full in logical_tables(model.embedding,
                                     params["embedding"]).items():
        pdb.create_table(model_name, name, full.shape[0], full.shape[1],
                         initial=full)
    if getattr(model, "wide", None) is not None:
        for name, full in logical_tables(model.wide,
                                         params["wide_embedding"]).items():
            pdb.create_table(model_name, name, full.shape[0],
                             full.shape[1], initial=full)
    pdb.flush()


class InferenceServer:

    def __init__(self, model, dense_params: Dict, hps: HPS, *,
                 max_batch: int = 1024, needs_wide: bool = False,
                 wide_hps: Optional[HPS] = None,
                 hotness: Optional[Sequence[int]] = None,
                 refresh_budget: int = 512,
                 refresh_poll_s: Optional[float] = None):
        self.model = model
        self.hps = hps
        self.wide_hps = wide_hps
        #: optional per-table hotness forwarded to HPS.lookup (validated
        #: there against the request shape)
        self.hotness = list(hotness) if hotness is not None else None
        self.dense_params = dense_params
        self.max_batch = max_batch
        #: rows re-pulled per refresh chunk between drained batches
        self.refresh_budget = refresh_budget
        #: period of the full-mark sweep (None = only bus-marked rows)
        self.refresh_poll_s = refresh_poll_s
        self.updates_applied = 0
        self.rows_refreshed = 0
        self._last_poll = time.monotonic()
        self._predict = jax.jit(
            lambda p, d, e, w: model.apply_dense(p, d, e, w))
        self._predict_nowide = jax.jit(
            lambda p, d, e: model.apply_dense(p, d, e, None))
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.latencies_ms: List[float] = []

    # -- synchronous path ---------------------------------------------------------

    def predict(self, dense: np.ndarray, cat: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        pipelined = len(self.hps.tables) > 1
        emb = self.hps.lookup(cat, self.hotness, pipelined=pipelined)
        if self.wide_hps is not None:
            wide = self.wide_hps.lookup(
                cat, self.hotness,
                pipelined=len(self.wide_hps.tables) > 1)
            out = self._predict(self.dense_params, jnp.asarray(dense),
                                emb, wide)
        else:
            out = self._predict_nowide(self.dense_params,
                                       jnp.asarray(dense), emb)
        out = np.asarray(jax.nn.sigmoid(out))
        self.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        return out

    # -- refresh scheduling (runs on the serve loop, between batches) -------------

    def _refresh_tick(self) -> None:
        """One serving-loop tick of update propagation: bus -> L2/L3 (+
        dirty marks), a periodic full-mark sweep, and ONE bounded
        hotness-ordered refresh chunk — never a stop-the-world re-pull.
        Covers every HPS this server reads from (deep AND wide)."""
        sweep = False
        if self.refresh_poll_s is not None:
            now = time.monotonic()
            if now - self._last_poll >= self.refresh_poll_s:
                self._last_poll = now
                sweep = True
        for hps in (self.hps, self.wide_hps):
            if hps is None:
                continue
            if hps.consumer is not None:
                self.updates_applied += hps.apply_updates()
            if sweep:
                hps.schedule_refresh()
            if hps.refresh_backlog():
                self.rows_refreshed += hps.refresh_step(self.refresh_budget)

    # -- queued/batched path --------------------------------------------------------

    def submit(self, dense: np.ndarray, cat: np.ndarray) -> "queue.Queue":
        done: queue.Queue = queue.Queue(maxsize=1)
        self._q.put((dense, cat, done))
        return done

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                self._refresh_tick()     # idle: drain the refresh backlog
                continue
            reqs = [first]
            rows = first[0].shape[0]
            while rows < self.max_batch:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                reqs.append(nxt)
                rows += nxt[0].shape[0]
            dense = np.concatenate([r[0] for r in reqs])
            cat = np.concatenate([r[1] for r in reqs])
            preds = self.predict(dense, cat)
            off = 0
            for r in reqs:
                n = r[0].shape[0]
                r[2].put(preds[off:off + n])
                off += n
            self._refresh_tick()         # interleave refresh with serving

    def start(self):
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    def stop(self):
        self._stop.set()
        if self._worker:
            self._worker.join()
            self._worker = None
        self._stop.clear()

    def latency_percentiles(self) -> Dict[str, float]:
        if not self.latencies_ms:
            return {}
        arr = np.asarray(self.latencies_ms)
        return {"p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
                "p99": float(np.percentile(arr, 99)),
                "mean": float(arr.mean())}
