"""Triton-style batched inference server backed by the HPS.

Request flow (paper Figure 2, red path): requests queue up, a batcher
drains up to ``max_batch`` of them, the HPS resolves embeddings (L1 device
cache -> L2 VDB -> L3 PDB), and the jitted dense net computes predictions.
``deploy_from_training`` exports a trained model into the PDB — the
offline-training deployment path; online updates arrive via the bus.

The serve loop is a STREAM-FED pipeline (``engine="stream"``, the
default): drained request groups feed the dense network directly from
``HPS.lookup_stream`` with no caller-thread materialization in between —
while query *i-1*'s prediction materializes, query *i*'s pooled
embeddings and dense net are computing on device and query *i+1*'s index
probes (and their remote L2/L3 miss fetches) run on the HPS host
workers. The only host sync point per query is the prediction itself.
Predictions are bit-identical to the unpipelined path: the per-plan
payload snapshots make the lookup machinery order-independent, and the
dense net is the same jitted function either way. Two reference engines
remain selectable: ``"sync"`` (drain -> one blocking ``predict`` per
group — the old loop, where XLA async dispatch still overlaps device
work behind the host) and ``"stage_sync"`` (every device stage blocked
before the next host stage — the no-overlap baseline the benchmarks
measure against).

The serve loop also drives update propagation (no bare timer threads):
between pipeline stages it polls the message bus into L2/L3, marks the
touched L1 rows dirty, and drains one bounded hotness-ordered refresh
chunk per tick — so refresh IO interleaves with serving instead of
stopping the world, and a periodic ``refresh_poll_s`` full-mark sweeps
rows whose updates arrived out of band.

``MultiModelServer`` fronts SEVERAL models from one storage backend —
per-model serve loops and L1 caches over a shared VolatileDB
(model-namespaced keys), a shared PersistentDB (model-namespaced tables)
and a shared message bus (model-scoped topics): the ensemble deployment
unit of the GPU-specialized inference parameter server (arXiv
2210.08804), reconstructed by ``launch.serve.build_server_from_config``
from one ps.json bundle.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EmbeddingTableConfig, RecsysConfig
from repro.core.hps.hps import HPS
from repro.core.hps.message_bus import MessageBus
from repro.core.hps.persistent_db import PersistentDB
from repro.core.hps.volatile_db import VolatileDB

ENGINES = ("stream", "sync", "stage_sync")


def deploy_from_training(model, params: Dict, pdb: PersistentDB,
                         model_name: str) -> None:
    """Export trained embedding tables into the PDB (ground truth copy).

    Wide models (wdl/deepfm) export BOTH table sets: the deep tables and
    their dim-1 ``*_wide`` twins, so the serving side can stand up the
    second HPS the wide branch needs.
    """
    from repro.models.recsys.model import logical_tables
    for name, full in logical_tables(model.embedding,
                                     params["embedding"]).items():
        pdb.create_table(model_name, name, full.shape[0], full.shape[1],
                         initial=full)
    if getattr(model, "wide", None) is not None:
        for name, full in logical_tables(model.wide,
                                         params["wide_embedding"]).items():
            pdb.create_table(model_name, name, full.shape[0],
                             full.shape[1], initial=full)
    pdb.flush()


class InferenceServer:

    # Checked by `python -m repro.analysis`: serving counters and the
    # latency samples are written by the serve-loop thread and read by
    # stats/benchmark callers, so they live behind _stats_lock.
    _GUARDED_BY = {
        "updates_applied": "_stats_lock",
        "rows_refreshed": "_stats_lock",
        "latencies_ms": "_stats_lock",
    }

    def __init__(self, model, dense_params: Dict, hps: HPS, *,
                 max_batch: int = 1024, needs_wide: bool = False,
                 wide_hps: Optional[HPS] = None,
                 hotness: Optional[Sequence[int]] = None,
                 refresh_budget: int = 512,
                 refresh_poll_s: Optional[float] = None,
                 engine: str = "stream"):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, "
                             f"got {engine!r}")
        self.model = model
        self.hps = hps
        self.wide_hps = wide_hps
        #: optional per-table hotness forwarded to HPS.lookup (validated
        #: there against the request shape)
        self.hotness = list(hotness) if hotness is not None else None
        self.dense_params = dense_params
        self.max_batch = max_batch
        self.engine = engine
        #: rows re-pulled per refresh chunk between drained batches
        self.refresh_budget = refresh_budget
        #: period of the full-mark sweep (None = only bus-marked rows)
        self.refresh_poll_s = refresh_poll_s
        self._stats_lock = threading.Lock()
        self.updates_applied = 0
        self.rows_refreshed = 0
        self._last_poll = time.monotonic()
        self._predict = jax.jit(
            lambda p, d, e, w: model.apply_dense(p, d, e, w))
        self._predict_nowide = jax.jit(
            lambda p, d, e: model.apply_dense(p, d, e, None))
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None
        self.latencies_ms: List[float] = []
        #: control-plane hook run at the end of every ``_refresh_tick``
        #: (the ensemble budget rebalancer registers itself here); must
        #: be cheap or internally rate-limited — it runs on the serve
        #: loop between pipeline stages
        self.on_tick: Optional[Callable[[], None]] = None

    def _record_latency(self, t0: float) -> None:
        ms = (time.perf_counter() - t0) * 1e3
        with self._stats_lock:
            self.latencies_ms.append(ms)

    # -- synchronous path ---------------------------------------------------------

    def _dense_forward(self, dense: np.ndarray, emb: jax.Array,
                       wide: Optional[jax.Array]) -> jax.Array:
        """The one jitted dense-net dispatch + host-side sigmoid — shared
        by every engine so outputs are bit-identical across them."""
        if wide is not None:
            out = self._predict(self.dense_params, jnp.asarray(dense),
                                emb, wide)
        else:
            out = self._predict_nowide(self.dense_params,
                                       jnp.asarray(dense), emb)
        return jax.nn.sigmoid(out)

    def predict(self, dense: np.ndarray, cat: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        pipelined = len(self.hps.tables) > 1
        emb = self.hps.lookup(cat, self.hotness, pipelined=pipelined)
        wide = None
        if self.wide_hps is not None:
            wide = self.wide_hps.lookup(
                cat, self.hotness,
                pipelined=len(self.wide_hps.tables) > 1)
        out = np.asarray(self._dense_forward(dense, emb, wide))
        self._record_latency(t0)
        return out

    def _predict_stage_sync(self, dense: np.ndarray,
                            cat: np.ndarray) -> np.ndarray:
        """The no-overlap reference: every embedding device stage blocks
        before the next host stage, the dense net blocks before the
        sigmoid — nothing is left to XLA's async dispatch."""
        t0 = time.perf_counter()
        emb = self.hps.lookup_stage_sync(cat, self.hotness)
        wide = None
        if self.wide_hps is not None:
            wide = self.wide_hps.lookup_stage_sync(cat, self.hotness)
        if wide is not None:
            out = self._predict(self.dense_params, jnp.asarray(dense),
                                emb, wide)
        else:
            out = self._predict_nowide(self.dense_params,
                                       jnp.asarray(dense), emb)
        out = np.asarray(jax.nn.sigmoid(jax.block_until_ready(out)))
        self._record_latency(t0)
        return out

    # -- refresh scheduling (runs on the serve loop, between batches) -------------

    def _refresh_tick(self) -> None:
        """One serving-loop tick of update propagation: bus -> L2/L3 (+
        dirty marks), a periodic full-mark sweep, and ONE bounded
        hotness-ordered refresh chunk — never a stop-the-world re-pull.
        Covers every HPS this server reads from (deep AND wide).

        Safe to interleave anywhere between pipeline stages: in-flight
        lookup plans carry their own lock-consistent payload snapshots,
        so a refresh scatter landing between a query's probe and its
        device stage can never tear that query's view."""
        sweep = False
        if self.refresh_poll_s is not None:
            now = time.monotonic()
            if now - self._last_poll >= self.refresh_poll_s:
                self._last_poll = now
                sweep = True
        applied = refreshed = 0            # the bus/refresh IO runs
        for hps in (self.hps, self.wide_hps):   # unlocked; counters
            if hps is None:                     # update in one step below
                continue
            if hps.consumer is not None:
                applied += hps.apply_updates()
            if sweep:
                hps.schedule_refresh()
            if hps.refresh_backlog():
                refreshed += hps.refresh_step(self.refresh_budget)
        if applied or refreshed:
            with self._stats_lock:
                self.updates_applied += applied
                self.rows_refreshed += refreshed
        if self.on_tick is not None:
            self.on_tick()

    # -- queued/batched path --------------------------------------------------------

    def submit(self, dense: np.ndarray, cat: np.ndarray) -> "queue.Queue":
        """Queue a request; the returned handle's ``get()`` yields the
        prediction rows (or the exception that failed its batch)."""
        done: queue.Queue = queue.Queue(maxsize=1)
        self._q.put((dense, cat, done))
        return done

    def _coalesce(self, first
                  ) -> Optional[Tuple[list, np.ndarray, np.ndarray]]:
        """Drain the queue behind ``first`` into one coalesced request
        group of up to ``max_batch`` rows (the batcher of the paper's
        Figure 2 — one group is one device batch). Requests that cannot
        be concatenated (mismatched widths) get the error delivered to
        their handles here and ``None`` comes back — the serve loop must
        keep running."""
        reqs = [first]
        rows = first[0].shape[0]
        while rows < self.max_batch:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            reqs.append(nxt)
            rows += nxt[0].shape[0]
        try:
            dense = np.concatenate([r[0] for r in reqs])
            cat = np.concatenate([r[1] for r in reqs])
        except Exception as exc:
            self._deliver_error(reqs, exc)
            return None
        return reqs, dense, cat

    @staticmethod
    def _deliver(reqs: list, preds: np.ndarray) -> None:
        off = 0
        for r in reqs:
            n = r[0].shape[0]
            r[2].put(preds[off:off + n])
            off += n

    @staticmethod
    def _deliver_error(reqs: list, exc: BaseException) -> None:
        for r in reqs:
            try:
                r[2].put_nowait(exc)
            except queue.Full:
                pass

    # -- the stream-fed pipeline (engine="stream") ----------------------------------

    def _serve_burst_stream(self, first) -> None:
        """Pipeline one burst of requests end-to-end: request groups are
        admitted into ``HPS.lookup_stream`` (host probes + remote
        fetches run ahead on the HPS workers), each yielded DEVICE
        embedding block feeds the jitted dense net immediately, and
        predictions materialize ONE GROUP BEHIND the dense dispatch —
        group *i+1* probes the host index while group *i*'s payload
        scatters + dense net run and group *i-1*'s prediction leaves for
        its callers. ``_refresh_tick`` interleaves between stages. The
        burst ends when the request queue goes empty; the pipeline then
        drains in order.
        """
        fifo: deque = deque()   # (reqs, dense, t0) in admission order
        head = [first]

        def cats():
            while True:
                if head:        # ALWAYS serve the already-dequeued
                    nxt = head.pop()    # request, even under stop()
                elif self._stop.is_set():
                    return      # stop only gates NEW admissions
                else:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        return
                group = self._coalesce(nxt)
                if group is None:           # un-concatenatable: errors
                    continue                # already delivered
                reqs, dense, cat = group
                if dense.shape[0] == 0:     # degenerate empty group
                    self._deliver(reqs, np.zeros((0,), np.float32))
                    continue
                fifo.append((reqs, dense, time.perf_counter()))
                yield cat

        if self.wide_hps is not None:
            deep_src, wide_src = itertools.tee(cats())
            pairs = zip(
                self.hps.lookup_stream(deep_src, self.hotness,
                                       materialize=False),
                self.wide_hps.lookup_stream(wide_src, self.hotness,
                                            materialize=False))
        else:
            pairs = ((emb, None) for emb in
                     self.hps.lookup_stream(cats(), self.hotness,
                                            materialize=False))

        in_flight: deque = deque()          # (reqs, t0, device preds)
        current = None                      # group between fifo/in_flight
        try:
            for emb, wide in pairs:
                current = fifo.popleft()    # (reqs, dense, t0)
                out = self._dense_forward(current[1], emb, wide)
                in_flight.append((current[0], current[2], out))
                current = None
                self._refresh_tick()        # between pipeline stages
                if len(in_flight) > 1:      # materialize one behind
                    self._materialize(in_flight.popleft())
            while in_flight:
                self._materialize(in_flight.popleft())
        except Exception as exc:            # a poisoned group kills the
            if current is not None:         # burst: surface the error to
                self._deliver_error(current[0], exc)  # EVERY undelivered
            for reqs, _, _ in in_flight:    # handle (the failing group's
                self._deliver_error(reqs, exc)   # own included) instead
            for reqs, _, _ in fifo:         # of hanging callers
                self._deliver_error(reqs, exc)

    def _materialize(self, item) -> None:
        reqs, t0, pred = item
        try:
            preds = np.asarray(pred)        # the one sync point per group
        except Exception as exc:            # deferred device error: this
            self._deliver_error(reqs, exc)  # group's handles first, the
            raise                           # burst handler does the rest
        self._record_latency(t0)
        self._deliver(reqs, preds)

    # -- serve loop -----------------------------------------------------------------

    def _serve_loop(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                self._refresh_tick()     # idle: drain the refresh backlog
                continue
            if self.engine == "stream":
                self._serve_burst_stream(first)
                continue
            group = self._coalesce(first)
            if group is None:               # errors already delivered
                self._refresh_tick()
                continue
            reqs, dense, cat = group
            try:
                if self.engine == "stage_sync":
                    preds = self._predict_stage_sync(dense, cat)
                else:
                    preds = self.predict(dense, cat)
            except Exception as exc:
                self._deliver_error(reqs, exc)
            else:
                self._deliver(reqs, preds)
            self._refresh_tick()         # interleave refresh with serving

    def start(self):
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    def stop(self):
        self._stop.set()
        if self._worker:
            self._worker.join()
            self._worker = None
        self._stop.clear()

    def latency_percentiles(self) -> Dict[str, float]:
        with self._stats_lock:
            arr = np.asarray(self.latencies_ms)
        if len(arr) == 0:
            return {}
        return {"p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
                "p99": float(np.percentile(arr, 99)),
                "mean": float(arr.mean())}

    def reset_latencies(self) -> None:
        """Drop accumulated latency samples (benchmark warmup reset)."""
        with self._stats_lock:
            self.latencies_ms = []

    def counters(self) -> Dict[str, int]:
        """Lock-consistent snapshot of the serving counters."""
        with self._stats_lock:
            return {"updates_applied": self.updates_applied,
                    "rows_refreshed": self.rows_refreshed,
                    "groups_served": len(self.latencies_ms)}


class MultiModelServer:
    """Several models served from ONE parameter-server process.

    Each member keeps its own serve loop, dense net and L1 device caches
    (embedding working sets must not thrash each other); the storage
    levels below are SHARED — one VolatileDB (keys namespaced
    ``model/table`` by the HPS), one PersistentDB (tables namespaced per
    model on disk) and one message bus (topics scoped
    ``hps.<model>.<table>``) — so adding a model to a deployment adds
    L1 state only, and one model's online updates can never touch
    another's tables at any level. Predictions are bit-exact with
    per-model in-process servers: sharing storage shares bytes, not
    values.

    With ``cache_budget`` AND ``rebalance_interval_s`` set, the shared
    L1 row budget is periodically RE-SPLIT from observed per-model miss
    pressure (the deploy-time split is static declared hotness —
    ``api.hotness_cache_capacities``): each member's serve loop tick
    calls into the rebalancer, which at most once per interval re-splits
    the budget proportional to each model's L1 miss delta since the last
    split and resizes the member caches (hottest rows retained). Opt-in
    because a resize recompiles the pooled gather for the new payload
    shape — leave it off when the hot-path sanitizer's zero-recompile
    contract matters more than cache efficiency.
    """

    # Checked by `python -m repro.analysis`: rebalance bookkeeping is
    # touched from every member's serve loop, so it lives behind the
    # rebalance lock (acquired non-blocking — serving never waits on it).
    _GUARDED_BY = {
        "_last_counts": "_rebalance_lock",
        "_last_rebalance": "_rebalance_lock",
        "rebalances": "_rebalance_lock",
    }

    def __init__(self, servers: Mapping[str, InferenceServer], *,
                 vdb: Optional[VolatileDB] = None,
                 pdb: Optional[PersistentDB] = None,
                 bus: Optional[MessageBus] = None,
                 cache_budget: Optional[int] = None,
                 rebalance_interval_s: Optional[float] = None,
                 rebalance_floor: int = 64):
        if not servers:
            raise ValueError("MultiModelServer needs at least one model")
        self.servers: Dict[str, InferenceServer] = dict(servers)
        self.vdb = vdb
        self.pdb = pdb
        self.bus = bus
        self.cache_budget = cache_budget
        self.rebalance_interval_s = rebalance_interval_s
        self.rebalance_floor = rebalance_floor
        self.rebalances = 0
        self._rebalance_lock = threading.Lock()
        self._last_counts: Dict[str, Tuple[int, int]] = {}
        self._last_rebalance = time.monotonic()
        if cache_budget is not None and rebalance_interval_s is not None:
            for s in self.servers.values():
                s.on_tick = self._rebalance_tick

    @property
    def models(self) -> List[str]:
        return list(self.servers)

    def __getitem__(self, model: str) -> InferenceServer:
        return self._server(model)

    def _server(self, model: str) -> InferenceServer:
        try:
            return self.servers[model]
        except KeyError:
            raise KeyError(f"unknown model {model!r}; serving "
                           f"{self.models}") from None

    def predict(self, model: str, dense: np.ndarray,
                cat: np.ndarray) -> np.ndarray:
        return self._server(model).predict(dense, cat)

    def submit(self, model: str, dense: np.ndarray,
               cat: np.ndarray) -> "queue.Queue":
        return self._server(model).submit(dense, cat)

    # -- observed-hit-rate budget rebalance ----------------------------------

    def _rebalance_tick(self) -> None:
        """Serve-loop hook: re-split the shared L1 budget at most once
        per ``rebalance_interval_s``. Non-blocking — if another member's
        loop is mid-rebalance, this tick just returns."""
        if not self._rebalance_lock.acquire(blocking=False):
            return
        try:  # the non-blocking acquire above holds the lock through here
            now = time.monotonic()
            # lock-ok: LOCK001 inside acquire(blocking=False)/finally-release — held, just not a with-block
            if now - self._last_rebalance < self.rebalance_interval_s:
                return
            # lock-ok: LOCK001 inside acquire(blocking=False)/finally-release — held, just not a with-block
            self._last_rebalance = now
            # lock-ok: LOCK004 inside acquire(blocking=False)/finally-release — held, just not a with-block
            self._rebalance_locked()
        finally:
            self._rebalance_lock.release()

    def rebalance_now(self) -> Dict[str, int]:
        """Force one budget re-split immediately (tests / operators);
        returns the per-model capacities now in effect."""
        with self._rebalance_lock:
            self._last_rebalance = time.monotonic()
            self._rebalance_locked()
        return {name: s.hps.cache_capacity
                for name, s in self.servers.items()}

    def _rebalance_locked(self) -> None:
        """Split ``cache_budget`` proportional to each model's observed
        L1 miss delta since the last split (+1 smoothing so an idle
        member keeps a foothold), floored so a cold member still serves,
        and resize members whose share moved more than 10% — small
        drifts are not worth the resize's gather recompile."""
        demand: Dict[str, int] = {}
        for name, s in self.servers.items():
            hits = misses = 0
            for c in s.hps.caches.values():
                cnt = c.counters()
                hits += cnt["hits"]
                misses += cnt["misses"]
            _, pm = self._last_counts.get(name, (0, 0))
            self._last_counts[name] = (hits, misses)
            demand[name] = (misses - pm) + 1
        total = sum(demand.values())
        moved = 0
        for name, d in demand.items():
            s = self.servers[name]
            floor = max(self.rebalance_floor, s.hps.cache_shards)
            cap = max(floor, int(round(self.cache_budget * d / total)))
            cur = s.hps.cache_capacity
            if abs(cap - cur) <= max(1, int(0.1 * cur)):
                continue
            s.hps.resize_caches(cap)
            if s.wide_hps is not None:
                s.wide_hps.resize_caches(cap)
            moved += 1
        if moved:
            self.rebalances += 1

    def start(self):
        for s in self.servers.values():
            s.start()

    def stop(self):
        for s in self.servers.values():
            s.stop()

    def stats(self) -> Dict[str, Dict]:
        """Per-model serving picture: L1/L2/L3 + refresh + latency."""
        out = {}
        for name, s in self.servers.items():
            c = s.counters()
            out[name] = {"hps": s.hps.stats(),
                         "cache_capacity": s.hps.cache_capacity,
                         "latency_ms": s.latency_percentiles(),
                         "updates_applied": c["updates_applied"],
                         "rows_refreshed": c["rows_refreshed"]}
        return out

    def rebalance_stats(self) -> Dict:
        """Budget-rebalancer picture: splits performed + current split."""
        with self._rebalance_lock:
            n = self.rebalances
        return {"rebalances": n, "cache_budget": self.cache_budget,
                "capacities": {name: s.hps.cache_capacity
                               for name, s in self.servers.items()}}
