"""Dynamic lock-order recorder — test-only instrumentation.

The static pass (``concurrency.py`` LOCK003) proves acyclicity of the
acquisition edges it can SEE; this module proves it for the edges that
actually HAPPEN. :class:`LockOrderRecorder` wraps live ``Lock``/
``RLock`` instances with :class:`_RecordingLock`, which forwards
``acquire``/``release`` (and the context-manager protocol) to the real
lock while maintaining a per-thread stack of held locks. Acquiring
lock B while holding lock A records the edge ``A -> B``; after a
concurrency hammer, ``assert_acyclic()`` fails with the offending
cycle if any two threads ever ordered the same pair of locks both
ways. Reentrant re-acquisition of a lock already on the thread's stack
records no edges (that is what RLocks are for).

Usage (see ``tests/test_hps_sharded.py``)::

    rec = LockOrderRecorder()
    rec.instrument_hps(hps)        # wraps cache/VDB/PDB/bus locks
    ... run the refresh/stream/update hammer ...
    assert rec.edges()             # the hammer really contended
    rec.assert_acyclic()

Instrumentation is per-instance (``setattr`` of the lock attribute),
so production code paths are untouched unless a test opts in.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple


class _RecordingLock:
    """Wraps a ``Lock``/``RLock``, reporting acquisitions to the
    recorder. Supports the subset of the lock API the repo uses:
    ``acquire``/``release`` and ``with``."""

    def __init__(self, inner, name: str, rec: "LockOrderRecorder"):
        self._inner = inner
        self._name = name
        self._rec = rec

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._rec._on_acquire(self._name)
        return got

    def release(self) -> None:
        self._rec._on_release(self._name)
        self._inner.release()

    def __enter__(self) -> "_RecordingLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class LockOrderRecorder:

    _GUARDED_BY = {"_edges": "_mu"}

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._edges: Dict[Tuple[str, str], int] = {}
        self._local = threading.local()

    # -- instrumentation -----------------------------------------------------

    def wrap(self, obj, attr: str = "_lock",
             name: Optional[str] = None) -> _RecordingLock:
        """Replace ``obj.<attr>`` with a recording wrapper (idempotent:
        an already-wrapped lock is returned as-is, so shared storage in
        ensembles is wrapped once)."""
        inner = getattr(obj, attr)
        if isinstance(inner, _RecordingLock):
            return inner
        rl = _RecordingLock(
            inner, name or f"{type(obj).__name__}.{attr}", self)
        setattr(obj, attr, rl)
        return rl

    def instrument_hps(self, hps, tag: str = "") -> None:
        """Wrap every lock an ``HPS`` stack can contend on: per-table
        L1 cache locks, the shared VDB/PDB locks, the L3 stats lock,
        the host-pool lock, and the message-bus lock (when wired)."""
        p = f"{tag}:" if tag else ""
        for tname, cache in hps.caches.items():
            self.wrap(cache, "_lock", f"{p}cache[{tname}]._lock")
        self.wrap(hps.vdb, "_lock", f"{p}VolatileDB._lock")
        self.wrap(hps.pdb, "_lock", f"{p}PersistentDB._lock")
        self.wrap(hps, "_l3_stats_lock", f"{p}HPS._l3_stats_lock")
        self.wrap(hps, "_pool_lock", f"{p}HPS._pool_lock")
        if hps.consumer is not None:
            self.wrap(hps.consumer.bus, "_lock", f"{p}MessageBus._lock")

    # -- recording (called with the wrapped lock just taken) -----------------

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _on_acquire(self, name: str) -> None:
        st = self._stack()
        if name not in st:      # reentrant re-acquire: no new edges
            held = list(dict.fromkeys(st))
            if held:
                with self._mu:
                    for h in held:
                        self._edges[(h, name)] = \
                            self._edges.get((h, name), 0) + 1
        st.append(name)

    def _on_release(self, name: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                return

    # -- inspection ----------------------------------------------------------

    def edges(self) -> Set[Tuple[str, str]]:
        with self._mu:
            return set(self._edges)

    def edge_counts(self) -> Dict[Tuple[str, str], int]:
        with self._mu:
            return dict(self._edges)

    def find_cycle(self) -> Optional[List[str]]:
        graph: Dict[str, Set[str]] = {}
        for a, b in self.edges():
            graph.setdefault(a, set()).add(b)

        WHITE, GREY, BLACK = 0, 1, 2
        color = {n: WHITE for n in graph}

        def dfs(n: str, stack: List[str]) -> Optional[List[str]]:
            color[n] = GREY
            stack.append(n)
            for m in sorted(graph.get(n, ())):
                if color.get(m, WHITE) == GREY:
                    return stack[stack.index(m):] + [m]
                if color.get(m, WHITE) == WHITE:
                    color.setdefault(m, WHITE)
                    cyc = dfs(m, stack)
                    if cyc:
                        return cyc
            stack.pop()
            color[n] = BLACK
            return None

        for n in sorted(graph):
            if color[n] == WHITE:
                cyc = dfs(n, [])
                if cyc:
                    return cyc
        return None

    def assert_acyclic(self) -> None:
        cyc = self.find_cycle()
        if cyc is not None:
            raise AssertionError(
                "lock-order cycle observed at runtime: "
                + " -> ".join(cyc))
