"""CLI for the analysis passes: ``python -m repro.analysis``.

Prints findings as ``file:line RULE message`` and a one-line summary.
``--check`` (the CI gate) exits non-zero on any live finding that is
neither inline-waived nor baselined, AND on stale baseline entries —
the baseline may only shrink. Informational findings (DEAD002) are
reported but never fail.

Stdlib-only: runs without jax installed (the lint and reachability
passes are pure AST walks).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List


def main(argv: List[str] = None) -> int:
    from repro.analysis import concurrency, deadcode
    from repro.analysis.findings import apply_baseline, load_baseline

    here = os.path.dirname(os.path.abspath(__file__))
    default_src = os.path.dirname(here)                   # src/repro

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="HPS concurrency lint + reachability report")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any non-baselined finding or "
                         "stale baseline entry (the CI gate)")
    ap.add_argument("--root", default=default_src,
                    help="package source tree to analyze "
                         "(default: the repro package)")
    ap.add_argument("--baseline",
                    default=os.path.join(here, "baseline.toml"),
                    help="shrink-only allowlist (default: the "
                         "checked-in analysis/baseline.toml)")
    ap.add_argument("--rules", default="lock,dead",
                    help="comma-set of passes to run: lock,dead")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print inline-waived findings")
    args = ap.parse_args(argv)

    src_root = os.path.abspath(args.root)
    repo_root = os.path.dirname(os.path.dirname(src_root))

    findings = []
    passes = {p.strip() for p in args.rules.split(",") if p.strip()}
    if "lock" in passes:
        findings += concurrency.lint_tree(src_root, repo_root)
    if "dead" in passes:
        findings += deadcode.lint(repo_root, src_root)

    entries = load_baseline(args.baseline) \
        if os.path.exists(args.baseline) else []
    live = [f for f in findings if not f.waived and not f.advice]
    failing, stale = apply_baseline(live, entries)
    baselined = {f.key() for f in live} - {f.key() for f in failing}

    shown = 0
    for f in findings:
        if f.waived and not args.show_waived:
            continue
        suffix = " (baselined)" if f.key() in baselined else ""
        print(f.format() + suffix)
        shown += 1
    for e in stale:
        print(f"{args.baseline}: stale [[allow]] entry {e!r} matches "
              "no current finding — the baseline only shrinks")

    n_waived = sum(1 for f in findings if f.waived)
    n_info = sum(1 for f in findings if f.advice)
    print(f"repro.analysis: {len(failing)} failing finding(s), "
          f"{len(baselined)} baselined, {n_waived} waived, "
          f"{n_info} informational, {len(stale)} stale baseline "
          f"entr{'y' if len(stale) == 1 else 'ies'}")
    if args.check and (failing or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
