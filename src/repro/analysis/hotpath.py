"""Hot-path sanitizer: runtime device-sync + recompile monitor
(SYNC001/SYNC002).

:class:`HotPathMonitor` is a context manager that instruments, for the
duration of the ``with`` block:

* **implicit device->host transfers** (``SYNC001``): ``numpy.asarray``
  / ``numpy.array`` / ``numpy.ascontiguousarray`` applied to a live
  ``jax.Array`` (the ``__array__`` protocol path), plus the explicit
  ``jax.block_until_ready`` / ``jax.device_get`` sync points;
* **jit compilations** (``SYNC002``): jax's
  ``/jax/core/compile/backend_compile_duration`` monitoring event,
  which fires only on FRESH compilations — cache hits are silent.

This is how tests pin the stream serve engine's contract: after
warmup, exactly ONE host sync per served group (the delivered
prediction in ``InferenceServer._materialize``) and ZERO recompiles.

The hooks are strictly scoped: module attributes are swapped on
``__enter__`` and restored to the original function objects on
``__exit__``, so disabled overhead is zero — outside a monitor,
``numpy.asarray`` IS the original numpy function, not a wrapper. One
jax monitoring listener is registered lazily on first use (jax has no
per-listener unregister) and is a no-op unless a monitor is active.
Monitors do not nest and there is at most one active process-wide.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, NamedTuple, Optional, Tuple


class SyncEvent(NamedTuple):
    kind: str       # "d2h" (host materialization) | "block" (sync wait)
    via: str        # entry point, e.g. "numpy.asarray"
    shape: Any      # shape of the device value, when it has one


COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_state_lock = threading.Lock()
_active: Optional["HotPathMonitor"] = None
_saved: Dict[Tuple[str, str], Any] = {}
_listener_on = False


def active_monitor() -> Optional["HotPathMonitor"]:
    """The currently-armed monitor, or None (the disabled state)."""
    return _active


def _on_event_duration(event: str, duration: float, **kw) -> None:
    mon = _active
    if mon is not None and event == COMPILE_EVENT:
        mon._note_compile(duration)


def _ensure_listener() -> None:
    global _listener_on
    if _listener_on:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_event_duration)
    _listener_on = True


def _install() -> None:
    import jax
    import numpy

    _ensure_listener()

    def np_hook(name: str, orig):
        def hooked(a, *args, **kwargs):
            mon = _active
            if mon is not None and isinstance(a, jax.Array):
                mon._note_sync("d2h", f"numpy.{name}",
                               getattr(a, "shape", None))
            return orig(a, *args, **kwargs)
        hooked._hotpath_orig = orig
        return hooked

    def jax_hook(name: str, kind: str, orig):
        def hooked(x, *args, **kwargs):
            mon = _active
            if mon is not None:
                mon._note_sync(kind, f"jax.{name}",
                               getattr(x, "shape", None))
            return orig(x, *args, **kwargs)
        hooked._hotpath_orig = orig
        return hooked

    for name in ("asarray", "array", "ascontiguousarray"):
        orig = getattr(numpy, name)
        _saved[("numpy", name)] = orig
        setattr(numpy, name, np_hook(name, orig))
    for name, kind in (("block_until_ready", "block"),
                       ("device_get", "d2h")):
        orig = getattr(jax, name)
        _saved[("jax", name)] = orig
        setattr(jax, name, jax_hook(name, kind, orig))


def _uninstall() -> None:
    import jax
    import numpy
    for (modname, name), orig in list(_saved.items()):
        setattr(numpy if modname == "numpy" else jax, name, orig)
    _saved.clear()


class HotPathMonitor:
    """Arm the sanitizer for a ``with`` block; see the module docstring.

    Event recording is thread-safe (the serve loop and HPS host workers
    run on their own threads), and attribution is process-global: every
    sync/compile anywhere in the process during the block is charged to
    this monitor.
    """

    _GUARDED_BY = {"syncs": "_mu", "compiles": "_mu",
                   "compile_secs": "_mu"}

    def __init__(self, label: str = ""):
        self.label = label
        self.syncs: List[SyncEvent] = []
        self.compiles = 0
        self.compile_secs = 0.0
        self._mu = threading.Lock()

    # -- recording (called from the hooks, any thread) -----------------------

    def _note_sync(self, kind: str, via: str, shape) -> None:
        with self._mu:
            self.syncs.append(SyncEvent(kind, via, shape))

    def _note_compile(self, duration: float) -> None:
        with self._mu:
            self.compiles += 1
            self.compile_secs += duration

    # -- inspection ----------------------------------------------------------

    @property
    def sync_count(self) -> int:
        with self._mu:
            return len(self.syncs)

    def events(self) -> List[SyncEvent]:
        with self._mu:
            return list(self.syncs)

    def summary(self) -> Dict[str, Any]:
        with self._mu:
            return {"label": self.label,
                    "syncs": len(self.syncs),
                    "d2h": sum(1 for e in self.syncs
                               if e.kind == "d2h"),
                    "block": sum(1 for e in self.syncs
                                 if e.kind == "block"),
                    "compiles": self.compiles,
                    "compile_secs": self.compile_secs}

    # -- arming --------------------------------------------------------------

    def __enter__(self) -> "HotPathMonitor":
        global _active
        with _state_lock:
            if _active is not None:
                raise RuntimeError(
                    "HotPathMonitor does not nest: one monitor may be "
                    "active per process")
            _install()
            _active = self
        return self

    def __exit__(self, *exc) -> bool:
        global _active
        with _state_lock:
            _active = None
            _uninstall()
        return False
