"""Repo-specific static analysis + runtime sanitizers for the HPS
serving stack.

The serving pipeline's correctness rests on concurrency invariants
that used to live only in prose — "refresh re-pulls rows with the
cache lock RELEASED", "the delivered prediction is the only host sync
point per group". This package turns them into machine-checked rules,
run by ``python -m repro.analysis`` (see ``__main__``) and gated in CI.

Passes and rule ids
-------------------

``concurrency`` — lock-discipline lint (static, AST):
    * ``LOCK001`` — attribute declared in a class's ``_GUARDED_BY``
      mapping accessed outside a ``with self.<lock>:`` scope.
    * ``LOCK002`` — blocking call while holding a lock: L2/L3 fetches
      (``fetch_fn``, ``pdb.fetch``/``upsert``, ``vdb.query``/
      ``insert``), ``time.sleep``, bus poll/publish, future
      ``.result``, thread ``.join``, pool ``.shutdown``,
      ``block_until_ready``, and ``np.asarray`` on a value that
      visibly comes off-device.
    * ``LOCK003`` — lock-order cycle in the static acquisition graph,
      or re-acquiring a held non-reentrant lock.
    * ``LOCK004`` — ``*_locked``-suffixed method (analyzed as
      lock-assumed-held) called without holding the lock.

``hotpath`` — runtime sanitizer (:class:`~.hotpath.HotPathMonitor`):
    * ``SYNC001`` — implicit device->host transfer (``numpy.asarray``
      et al. on a ``jax.Array``, ``jax.device_get``) or blocking sync
      (``jax.block_until_ready``) inside the monitored region.
    * ``SYNC002`` — fresh jit compilation inside the monitored region
      (post-warmup recompile).

``deadcode`` — import-graph reachability:
    * ``DEAD001`` — module unreachable from every entry point
      (``launch/*``, ``api``, ``__main__`` modules, benchmarks,
      examples, tests).
    * ``DEAD002`` — module reachable only from tests (informational).

``lockorder`` — :class:`~.lockorder.LockOrderRecorder`, the dynamic
counterpart of LOCK003: wraps live locks during a test hammer and
asserts the OBSERVED acquisition graph is acyclic.

Conventions
-----------

* Guard contracts are class attributes:
  ``_GUARDED_BY = {"attr": "_lockattr", ...}``; injected callables
  declare their lock footprint with
  ``_LOCKS_OF = {"attr": ("Class._lock", ...)}``.
* Intentional findings carry ``# lock-ok: RULE reason`` on the line or
  the line above; grandfathered findings live in ``baseline.toml``,
  which may only shrink (stale entries fail ``--check``).

Everything importable from this package's static passes is
stdlib-only, so the CLI runs in CI without jax installed; only
``hotpath`` touches jax, and only when a monitor is armed.
"""
from repro.analysis.findings import Finding, apply_baseline, load_baseline
from repro.analysis.hotpath import HotPathMonitor, active_monitor
from repro.analysis.lockorder import LockOrderRecorder

__all__ = [
    "Finding", "apply_baseline", "load_baseline",
    "HotPathMonitor", "active_monitor", "LockOrderRecorder",
]
