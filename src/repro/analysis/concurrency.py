"""Lock-discipline lint for the HPS serving stack (LOCK001-LOCK004).

A two-phase AST pass over the source tree:

1. **Collect.** Every concurrent class declares its contract with a
   plain class attribute ``_GUARDED_BY = {"attr": "_lockattr", ...}``.
   The pass additionally records which instance attributes hold
   ``threading.Lock``/``RLock`` objects, which attributes are instances
   of other collected classes (from ``__init__`` assignments, parameter
   annotations and ``self.x: T`` annotations), and the optional
   ``_LOCKS_OF = {"attr": ("Class._lock", ...)}`` declaration for
   injected callables whose lock footprint the AST cannot see (e.g.
   ``DeviceEmbeddingCache.fetch_fn`` — the HPS L2/L3 fall-through
   closure).

2. **Analyze.** Each method body is walked with the set of HELD locks
   tracked through ``with self._lock:`` scopes. A method whose name
   ends in ``_locked`` is analyzed as if the class's primary lock is
   held — and calling one without that lock is its own finding. Nested
   functions and lambdas run later, usually on another thread, so they
   start with no lock held.

Rules:

``LOCK001``
    guarded attribute accessed outside its declared lock
``LOCK002``
    blocking call while holding a lock: L2/L3 fetch, ``time.sleep``,
    bus poll/publish, future ``.result``, thread ``.join``, pool
    ``.shutdown``, ``block_until_ready``, or a ``np.asarray``/
    ``np.array`` forcing a device->host sync (argument visibly produces
    a device value). This encodes the PR 2 refresh invariant: slow IO
    and device syncs never run under a cache lock.
``LOCK003``
    lock-order cycle in the static acquisition graph (including
    re-acquiring a non-reentrant lock)
``LOCK004``
    ``*_locked`` method called without holding the lock

Intentional exceptions carry an inline waiver on the offending line or
the line directly above::

    # lock-ok: LOCK002 <why this blocking call must hold the lock>

Waived findings are reported (tagged) but do not fail ``--check``.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding, waiver_on

#: call-path suffixes treated as blocking/slow while a lock is held
BLOCKING_SUFFIXES: Tuple[Tuple[str, ...], ...] = (
    ("time", "sleep"),
    ("jax", "block_until_ready"),
    ("block_until_ready",),
    ("fetch_fn",),                   # the injected L2/L3 fall-through
    ("pdb", "fetch"), ("pdb", "upsert"),
    ("vdb", "query"), ("vdb", "insert"), ("vdb", "evict"),
    ("bus", "fetch"), ("bus", "publish"),
    ("consumer", "poll"),
    ("apply_updates",),
    ("refresh_step",), ("refresh_chunk",), ("refresh_once",),
    ("refresh_caches",),
    ("result",), ("join",), ("shutdown",),
)
#: suffix-colliding helpers that are NOT blocking
NONBLOCKING_OVERRIDES: Tuple[Tuple[str, ...], ...] = (
    ("os", "path", "join"), ("path", "join"), ("sep", "join"),
)
#: numpy entry points that force a device->host transfer when handed a
#: live device value
NUMPY_SYNC_CALLS = {("np", "asarray"), ("np", "array"),
                    ("numpy", "asarray"), ("numpy", "array")}
#: attribute calls whose result is (or binds) a device value — feeding
#: one into ``np.asarray`` under a lock is a device sync under a lock
DEVICE_PRODUCING = {"snapshot", "gather", "commit", "block_until_ready"}


@dataclass
class ClassInfo:
    name: str
    file: str                                   # repo-relative path
    line: int = 0
    guarded: Dict[str, str] = field(default_factory=dict)
    locks: Dict[str, str] = field(default_factory=dict)  # attr -> kind
    attr_types: Dict[str, Set[str]] = field(default_factory=dict)
    locks_of: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    methods: Set[str] = field(default_factory=set)
    #: method name -> own lock attrs its body acquires directly
    method_acquires: Dict[str, Set[str]] = field(default_factory=dict)

    @property
    def primary_lock(self) -> Optional[str]:
        if "_lock" in self.locks:
            return "_lock"
        if len(self.locks) == 1:
            return next(iter(self.locks))
        return None

    def qual(self, lockattr: str) -> str:
        return f"{self.name}.{lockattr}"


def _dotted(node: ast.AST) -> Tuple[str, ...]:
    """Call-path of an expression: ``self.vdb.query`` ->
    ``("self", "vdb", "query")``. Subscripts/calls are skipped; a
    non-name base becomes ``"?"``."""
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            break
        else:
            parts.append("?")
            break
    return tuple(reversed(parts))


def _annotation_names(node: Optional[ast.AST]) -> Set[str]:
    """Class names referenced by an annotation (quoted forms parsed)."""
    out: Set[str] = set()
    if node is None:
        return out
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            try:
                out |= _annotation_names(ast.parse(n.value, mode="eval"))
            except SyntaxError:
                pass
    return out


def _is_lock_ctor(value: ast.AST) -> Optional[str]:
    if not isinstance(value, ast.Call):
        return None
    path = _dotted(value.func)
    if path[-1] in ("Lock", "RLock") and \
            (len(path) == 1 or path[-2] == "threading"):
        return "rlock" if path[-1] == "RLock" else "lock"
    return None


def _const_str_dict(value: ast.AST) -> Dict[str, object]:
    out: Dict[str, object] = {}
    if not isinstance(value, ast.Dict):
        return out
    for k, v in zip(value.keys, value.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            continue
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            out[k.value] = v.value
        elif isinstance(v, (ast.Tuple, ast.List)):
            elems = tuple(e.value for e in v.elts
                          if isinstance(e, ast.Constant)
                          and isinstance(e.value, str))
            out[k.value] = elems
    return out


def _scan_init(fn: ast.FunctionDef, info: ClassInfo) -> None:
    """Harvest lock attrs and attr->class bindings from ``__init__``."""
    ann_of_param: Dict[str, Set[str]] = {}
    args = fn.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)):
        names = _annotation_names(a.annotation)
        if names:
            ann_of_param[a.arg] = names

    for node in ast.walk(fn):
        target = None
        value = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign):
            target, value = node.target, node.value
        if not (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            continue
        attr = target.attr
        if isinstance(node, ast.AnnAssign):
            names = _annotation_names(node.annotation)
            if names:
                info.attr_types.setdefault(attr, set()).update(names)
        if value is None:
            continue
        kind = _is_lock_ctor(value)
        if kind:
            info.locks[attr] = kind
            continue
        types: Set[str] = set()
        for n in ast.walk(value):
            if isinstance(n, ast.Call) and isinstance(n.func, ast.Name):
                types.add(n.func.id)
            elif isinstance(n, ast.Name) and n.id in ann_of_param:
                types |= ann_of_param[n.id]
        if types:
            info.attr_types.setdefault(attr, set()).update(types)


def _collect_class(node: ast.ClassDef, relpath: str) -> ClassInfo:
    info = ClassInfo(name=node.name, file=relpath, line=node.lineno)
    fns = [s for s in node.body
           if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for stmt in node.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            tname = stmt.targets[0].id
            if tname == "_GUARDED_BY":
                info.guarded = {k: v for k, v in
                                _const_str_dict(stmt.value).items()
                                if isinstance(v, str)}
            elif tname == "_LOCKS_OF":
                info.locks_of = {k: v for k, v in
                                 _const_str_dict(stmt.value).items()
                                 if isinstance(v, tuple)}
    for fn in fns:
        info.methods.add(fn.name)
        if fn.name == "__init__":
            _scan_init(fn, info)
    # a guard declaration implies the lock attr even if the collector
    # did not spot its constructor
    for lockattr in set(info.guarded.values()) - set(info.locks):
        info.locks[lockattr] = "unknown"
    for fn in fns:
        acquires: Set[str] = set()
        for n in ast.walk(fn):
            if isinstance(n, (ast.With, ast.AsyncWith)):
                for item in n.items:
                    ce = item.context_expr
                    if isinstance(ce, ast.Attribute) \
                            and isinstance(ce.value, ast.Name) \
                            and ce.value.id == "self" \
                            and ce.attr in info.locks:
                        acquires.add(ce.attr)
        info.method_acquires[fn.name] = acquires
    return info


class _Edges:
    """Static lock-acquisition graph: qualified lock -> qualified lock,
    with the first site that produced each edge."""

    def __init__(self) -> None:
        self.graph: Dict[str, Set[str]] = {}
        self.site: Dict[Tuple[str, str], Tuple[str, int, str]] = {}

    def add(self, src: str, dst: str, file: str, line: int,
            symbol: str) -> None:
        self.graph.setdefault(src, set()).add(dst)
        self.site.setdefault((src, dst), (file, line, symbol))


class _Analyzer:
    def __init__(self, classes: Dict[str, ClassInfo],
                 lock_kind: Dict[str, str]) -> None:
        self.classes = classes
        self.lock_kind = lock_kind
        self.findings: List[Finding] = []
        self.edges = _Edges()
        self._seen: Set[Tuple] = set()
        self._acq_memo: Dict[str, Set[str]] = {}

    # -- transitive lock footprint per class ---------------------------------

    def may_acquire(self, cls_name: str,
                    _stack: Tuple[str, ...] = ()) -> Set[str]:
        if cls_name in self._acq_memo:
            return self._acq_memo[cls_name]
        if cls_name in _stack:
            return set()
        cls = self.classes.get(cls_name)
        if cls is None:
            return set()
        out = {cls.qual(la) for la in cls.locks}
        for targets in cls.locks_of.values():
            out |= set(targets)
        for types in cls.attr_types.values():
            for t in types:
                out |= self.may_acquire(t, _stack + (cls_name,))
        if not _stack:
            self._acq_memo[cls_name] = out
        return out

    # -- per-file analysis ---------------------------------------------------

    def analyze_file(self, relpath: str, tree: ast.Module,
                     lines: List[str]) -> None:
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                cls = self.classes.get(node.name)
                if cls is None or not cls.locks:
                    continue
                for stmt in node.body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self._analyze_method(cls, stmt, relpath, lines)

    def _report(self, rule: str, relpath: str, line: int, msg: str,
                symbol: str, lines: List[str]) -> None:
        key = (relpath, rule, line, msg)
        if key in self._seen:
            return
        self._seen.add(key)
        reason = waiver_on(lines, line, rule)
        self.findings.append(Finding(
            rule=rule, file=relpath, line=line, message=msg,
            symbol=symbol, waived=reason is not None,
            waive_reason=reason or ""))

    def _analyze_method(self, cls: ClassInfo, fn: ast.FunctionDef,
                        relpath: str, lines: List[str]) -> None:
        if fn.name in ("__init__", "__del__"):
            return      # construction/teardown is single-threaded
        held: FrozenSet[str] = frozenset()
        if fn.name.endswith("_locked") and cls.primary_lock:
            held = frozenset({cls.qual(cls.primary_lock)})
        symbol = f"{cls.name}.{fn.name}"
        ctx = (cls, relpath, lines, symbol)
        for stmt in fn.body:
            self._visit(stmt, held, ctx)

    def _lock_of_with_item(self, ce: ast.AST,
                           cls: ClassInfo) -> Optional[str]:
        if isinstance(ce, ast.Attribute) \
                and isinstance(ce.value, ast.Name) \
                and ce.value.id == "self" and ce.attr in cls.locks:
            return ce.attr
        return None

    def _visit(self, node: ast.AST, held: FrozenSet[str], ctx) -> None:
        cls, relpath, lines, symbol = ctx
        if isinstance(node, (ast.With, ast.AsyncWith)):
            new = set(held)
            for item in node.items:
                la = self._lock_of_with_item(item.context_expr, cls)
                if la is None:
                    self._visit(item.context_expr, held, ctx)
                    continue
                q = cls.qual(la)
                self._edge_from_held(held, {q}, relpath,
                                     item.context_expr.lineno, symbol,
                                     lines)
                new.add(q)
            fheld = frozenset(new)
            for b in node.body:
                self._visit(b, fheld, ctx)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested function: runs later, with no lock held
            for b in node.body:
                self._visit(b, frozenset(), ctx)
            return
        if isinstance(node, ast.Lambda):
            self._visit(node.body, frozenset(), ctx)
            return
        if isinstance(node, ast.Call):
            self._check_call(node, held, ctx)
        elif isinstance(node, ast.Attribute):
            self._check_attr(node, held, ctx)
        for child in ast.iter_child_nodes(node):
            self._visit(child, held, ctx)

    def _check_attr(self, node: ast.Attribute, held: FrozenSet[str],
                    ctx) -> None:
        cls, relpath, lines, symbol = ctx
        if not (isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return
        lockattr = cls.guarded.get(node.attr)
        if lockattr is None:
            return
        if cls.qual(lockattr) not in held:
            self._report(
                "LOCK001", relpath, node.lineno,
                f"guarded attribute '{node.attr}' accessed without "
                f"holding self.{lockattr}", symbol, lines)

    def _is_blocking(self, path: Tuple[str, ...]) -> bool:
        for suf in NONBLOCKING_OVERRIDES:
            if path[-len(suf):] == suf:
                return False
        if len(path) >= 2 and path[-2] == "?":
            return False          # e.g. ", ".join(...) — literal base
        for suf in BLOCKING_SUFFIXES:
            if path[-len(suf):] == suf:
                return True
        return False

    def _check_call(self, node: ast.Call, held: FrozenSet[str],
                    ctx) -> None:
        cls, relpath, lines, symbol = ctx
        path = _dotted(node.func)

        # LOCK004: self.x_locked() without the lock
        if len(path) == 2 and path[0] == "self" \
                and path[1].endswith("_locked") \
                and path[1] in cls.methods and cls.primary_lock:
            if cls.qual(cls.primary_lock) not in held:
                self._report(
                    "LOCK004", relpath, node.lineno,
                    f"'{path[1]}' assumes self.{cls.primary_lock} is "
                    "held but the caller does not hold it",
                    symbol, lines)

        if not held:
            return
        held_s = ", ".join(sorted(held))

        # LOCK002: blocking call under a lock
        if self._is_blocking(path):
            self._report(
                "LOCK002", relpath, node.lineno,
                f"blocking call '{'.'.join(path)}' while holding "
                f"{held_s}", symbol, lines)
        elif path in NUMPY_SYNC_CALLS and self._args_produce_device(node):
            self._report(
                "LOCK002", relpath, node.lineno,
                f"'{'.'.join(path)}' forces a device->host sync while "
                f"holding {held_s}", symbol, lines)

        # lock-order edges from cross-class / declared-callable calls
        targets: Set[str] = set()
        if len(path) >= 2 and path[0] == "self":
            attr = path[1]
            if attr in cls.locks_of:
                targets |= set(cls.locks_of[attr])
            elif len(path) == 2 and attr in cls.methods:
                targets |= {cls.qual(la) for la in
                            cls.method_acquires.get(attr, ())}
            elif attr in cls.attr_types:
                for t in cls.attr_types[attr]:
                    targets |= self.may_acquire(t)
        self._edge_from_held(held, targets, relpath, node.lineno,
                             symbol, lines)

    @staticmethod
    def _args_produce_device(node: ast.Call) -> bool:
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            for n in ast.walk(arg):
                if isinstance(n, ast.Call) \
                        and isinstance(n.func, ast.Attribute) \
                        and n.func.attr in DEVICE_PRODUCING:
                    return True
        return False

    def _edge_from_held(self, held: FrozenSet[str], targets: Set[str],
                        relpath: str, line: int, symbol: str,
                        lines: List[str]) -> None:
        for t in targets:
            for h in held:
                if t == h:
                    if self.lock_kind.get(t) == "lock":
                        self._report(
                            "LOCK003", relpath, line,
                            f"re-acquiring non-reentrant lock {t} "
                            "already held (self-deadlock)",
                            symbol, lines)
                    continue    # RLock re-entry: no edge
                self.edges.add(h, t, relpath, line, symbol)

    # -- cycle detection over the accumulated edge graph ---------------------

    def report_cycles(self) -> None:
        graph = self.edges.graph
        seen_cycles: Set[Tuple[str, ...]] = set()

        def dfs(n: str, stack: List[str], on_stack: Set[str],
                done: Set[str]) -> None:
            on_stack.add(n)
            stack.append(n)
            for m in sorted(graph.get(n, ())):
                if m in on_stack:
                    cyc = stack[stack.index(m):] + [m]
                    base = cyc[:-1]
                    k = min(range(len(base)),
                            key=lambda i: base[i])
                    canon = tuple(base[k:] + base[:k])
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        file, line, symbol = self.edges.site[
                            (cyc[0], cyc[1])]
                        self.findings.append(Finding(
                            rule="LOCK003", file=file, line=line,
                            message="lock-order cycle: "
                                    + " -> ".join(cyc),
                            symbol=symbol))
                elif m not in done:
                    dfs(m, stack, on_stack, done)
            stack.pop()
            on_stack.discard(n)
            done.add(n)

        done: Set[str] = set()
        for n in sorted(graph):
            if n not in done:
                dfs(n, [], set(), done)


def _parse(path: str) -> Tuple[Optional[ast.Module], List[str]]:
    with open(path) as f:
        src = f.read()
    try:
        return ast.parse(src, filename=path), src.splitlines()
    except SyntaxError:
        return None, src.splitlines()


def lint_paths(paths: Sequence[str],
               repo_root: Optional[str] = None) -> List[Finding]:
    """Run the lock lint over explicit files (two-phase: classes are
    collected from ALL given files before any is analyzed, so
    cross-file lock-order edges resolve)."""
    repo_root = repo_root or os.getcwd()
    parsed: List[Tuple[str, ast.Module, List[str]]] = []
    classes: Dict[str, ClassInfo] = {}
    for path in paths:
        tree, lines = _parse(path)
        if tree is None:
            continue
        rel = os.path.relpath(path, repo_root)
        parsed.append((rel, tree, lines))
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                classes.setdefault(node.name,
                                   _collect_class(node, rel))
    lock_kind = {c.qual(la): kind
                 for c in classes.values()
                 for la, kind in c.locks.items()}
    an = _Analyzer(classes, lock_kind)
    for rel, tree, lines in parsed:
        an.analyze_file(rel, tree, lines)
    an.report_cycles()
    an.findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return an.findings


def lint_tree(root: str,
              repo_root: Optional[str] = None) -> List[Finding]:
    """Run the lock lint over every ``*.py`` under ``root``."""
    files = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                files.append(os.path.join(dirpath, fn))
    return lint_paths(files, repo_root)
