"""Finding model, inline waivers, and the shrink-only baseline.

Every analysis pass reports :class:`Finding` objects that print as
``file:line RULE message`` — the grep/CI-friendly shape.

Two escape hatches exist, with different lifetimes:

* **Inline waivers** (``# lock-ok: RULE reason``, on the offending line
  or the line directly above) mark *intentional designs* the rule
  cannot distinguish from bugs. They live next to the code, carry their
  justification, and are reviewed whenever the code changes. Waived
  findings are still reported (tagged) but never fail ``--check``.
* **``baseline.toml``** grandfathers *pre-existing findings* at the
  moment a pass is introduced. It may only SHRINK: an entry that no
  longer matches any live finding is *stale* and fails ``--check``, so
  the file cannot rot into a permanent allowlist.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Finding:
    rule: str
    file: str                  # repo-relative path
    line: int
    message: str
    symbol: str = ""           # "Class.method" when known
    waived: bool = False
    waive_reason: str = ""
    advice: bool = False       # informational: reported, never fails

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        tag = " (waived)" if self.waived else \
              " (info)" if self.advice else ""
        return f"{self.file}:{self.line} {self.rule} {self.message}" \
               f"{sym}{tag}"

    def key(self) -> Tuple[str, str, int, str]:
        return (self.file, self.rule, self.line, self.message)


_WAIVER_RE = re.compile(r"#\s*lock-ok:\s*([A-Z]+\d+)\b\s*(.*)")


def waiver_on(lines: Sequence[str], lineno: int,
              rule: str) -> Optional[str]:
    """Return the waiver reason if ``lines`` carries an inline
    ``# lock-ok: <rule>`` marker on ``lineno`` (1-based) or the line
    directly above it."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _WAIVER_RE.search(lines[ln - 1])
            if m and m.group(1) == rule:
                return m.group(2).strip() or "waived"
    return None


# -- baseline (minimal TOML subset: [[allow]] tables of scalars) --------------

_KV_STR = re.compile(r'^(\w+)\s*=\s*"([^"]*)"\s*(?:#.*)?$')
_KV_INT = re.compile(r"^(\w+)\s*=\s*(\d+)\s*(?:#.*)?$")


def load_baseline(path: str) -> List[Dict[str, object]]:
    """Parse ``baseline.toml``: a list of ``[[allow]]`` tables with
    string/int values. Hand-rolled because the floor interpreter is
    3.10 (no ``tomllib``) and the analysis CLI must stay stdlib-only."""
    entries: List[Dict[str, object]] = []
    cur: Optional[Dict[str, object]] = None
    with open(path) as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line == "[[allow]]":
                cur = {}
                entries.append(cur)
                continue
            m = _KV_STR.match(line)
            if m and cur is not None:
                cur[m.group(1)] = m.group(2)
                continue
            m = _KV_INT.match(line)
            if m and cur is not None:
                cur[m.group(1)] = int(m.group(2))
                continue
            raise ValueError(f"{path}: cannot parse line {line!r}")
    return entries


def _matches(entry: Dict[str, object], f: Finding) -> bool:
    if entry.get("rule") != f.rule or entry.get("file") != f.file:
        return False
    if "line" in entry and entry["line"] != f.line:
        return False
    if "symbol" in entry and entry["symbol"] != f.symbol:
        return False
    return True


def apply_baseline(findings: Sequence[Finding],
                   entries: Sequence[Dict[str, object]]
                   ) -> Tuple[List[Finding], List[Dict[str, object]]]:
    """Split live findings against the baseline.

    Returns ``(unmatched_findings, stale_entries)``: findings no entry
    covers (these fail ``--check``) and entries covering nothing (these
    ALSO fail ``--check`` — the baseline may only shrink)."""
    used = [False] * len(entries)
    unmatched: List[Finding] = []
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if _matches(e, f):
                used[i] = True
                hit = True
        if not hit:
            unmatched.append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return unmatched, stale
