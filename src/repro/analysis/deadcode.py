"""Reachability report over the import graph (DEAD001/DEAD002).

Walks every module under the source package, extracts its static
imports (plus dotted-module string literals, which cover the
``importlib``-driven recipe registry and config loading), and BFSes
from the entry points:

* **runtime roots** — ``<pkg>.launch.*``, ``<pkg>.api``, any
  ``__main__`` module, and whatever ``benchmarks/`` and ``examples/``
  import;
* **test roots** — whatever ``tests/`` imports.

Rules:

``DEAD001``
    module unreachable from ANY entry point (orphan) — fails
    ``--check``
``DEAD002``
    module reachable only from tests (informational: it may be a test
    utility, or it may be a feature that lost its product entry point)

A string literal that names a package prefix ending in a dot (e.g.
``"repro.configs."``) marks every submodule of that package reachable —
the dynamic-import idiom used by the recipe registry.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.findings import Finding


def _py_modules(src_root: str) -> Dict[str, str]:
    """Dotted module name -> file path for the package at ``src_root``."""
    pkg = os.path.basename(os.path.normpath(src_root))
    mods: Dict[str, str] = {}
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, src_root)
            parts = [pkg] + rel[:-3].split(os.sep)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            mods[".".join(parts)] = full
    return mods


def _walk_py(root: str) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def _edges_from_file(path: str, mods: Dict[str, str],
                     cur_mod: Optional[str] = None,
                     is_package: bool = False) -> Set[str]:
    try:
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
    except (SyntaxError, OSError):
        return set()
    out: Set[str] = set()

    def mark(name: str) -> None:
        """Add ``name`` and its ancestor packages (their __init__ runs)."""
        parts = name.split(".")
        for i in range(1, len(parts) + 1):
            cand = ".".join(parts[:i])
            if cand in mods:
                out.add(cand)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mark(a.name)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:
                if cur_mod is None:
                    continue
                pkg_parts = cur_mod.split(".")
                if not is_package:
                    pkg_parts = pkg_parts[:-1]
                pkg_parts = pkg_parts[:len(pkg_parts) - (node.level - 1)]
                base = ".".join(pkg_parts + ([base] if base else []))
            if base:
                mark(base)
            for a in node.names:
                if base and f"{base}.{a.name}" in mods:
                    mark(f"{base}.{a.name}")
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            s = node.value
            if s in mods:
                mark(s)
            elif s.endswith(".") and "." in s[:-1]:
                # dynamic-import prefix ("repro.configs." + arch):
                # conservatively mark the whole subpackage reachable.
                # Single-component prefixes ("repro.") are ignored as
                # too broad to be a meaningful edge.
                for m in mods:
                    if m.startswith(s):
                        mark(m)
    return out


@dataclass
class Report:
    modules: Dict[str, str]
    runtime: Set[str] = field(default_factory=set)
    test_only: Set[str] = field(default_factory=set)
    orphans: Set[str] = field(default_factory=set)


def reachability(repo_root: str, src_root: str, *,
                 runtime_dirs: Sequence[str] = ("benchmarks", "examples"),
                 test_dirs: Sequence[str] = ("tests",)) -> Report:
    mods = _py_modules(src_root)
    pkg = os.path.basename(os.path.normpath(src_root))
    edges = {
        m: _edges_from_file(
            p, mods, cur_mod=m,
            is_package=os.path.basename(p) == "__init__.py")
        for m, p in mods.items()}

    def external_seeds(dirs: Sequence[str]) -> Set[str]:
        seeds: Set[str] = set()
        for d in dirs:
            full = os.path.join(repo_root, d)
            if os.path.isdir(full):
                for f in _walk_py(full):
                    seeds |= _edges_from_file(f, mods)
        return seeds

    runtime_seeds = {m for m in mods
                     if m == f"{pkg}.api"
                     or m.startswith(f"{pkg}.launch")
                     or m.rsplit(".", 1)[-1] == "__main__"}
    runtime_seeds |= external_seeds(runtime_dirs)
    test_seeds = external_seeds(test_dirs)

    def bfs(seeds: Set[str]) -> Set[str]:
        seen: Set[str] = set()
        frontier = list(seeds)
        while frontier:
            m = frontier.pop()
            if m in seen or m not in mods:
                continue
            seen.add(m)
            # ancestor packages import too
            parts = m.split(".")
            for i in range(1, len(parts)):
                anc = ".".join(parts[:i])
                if anc in mods and anc not in seen:
                    frontier.append(anc)
            frontier.extend(edges.get(m, ()))
        return seen

    runtime = bfs(runtime_seeds)
    with_tests = bfs(runtime_seeds | test_seeds)
    return Report(modules=mods, runtime=runtime,
                  test_only=with_tests - runtime,
                  orphans=set(mods) - with_tests)


def lint(repo_root: str, src_root: str, *,
         include_test_only: bool = True) -> List[Finding]:
    rep = reachability(repo_root, src_root)
    findings: List[Finding] = []
    for m in sorted(rep.orphans):
        findings.append(Finding(
            rule="DEAD001",
            file=os.path.relpath(rep.modules[m], repo_root),
            line=1,
            message=f"module {m} is unreachable from every entry point "
                    "(launch/*, api, __main__, benchmarks, examples, "
                    "tests)"))
    if include_test_only:
        for m in sorted(rep.test_only):
            findings.append(Finding(
                rule="DEAD002",
                file=os.path.relpath(rep.modules[m], repo_root),
                line=1,
                message=f"module {m} is reachable only from tests",
                advice=True))
    return findings
