"""Criteo Terabyte / Kaggle format reader (the paper's benchmark dataset).

Format: TSV lines ``label \t I1..I13 \t C1..C26`` where I* are ints (may be
empty) and C* are 8-hex-digit category hashes (may be empty). Ids are
hashed into each table's vocab with a stable fingerprint, as HugeCTR's
data preprocessing does.

``CriteoReader`` is the SEEKABLE entry point: ``batch(step)`` is a pure
function of ``(file contents, batch_size, step)`` — batch ``s`` holds
lines ``[s*B, (s+1)*B)`` of the endlessly-looped file — so a
fault-tolerant trainer can replay any step after a restore exactly, the
same stateless contract ``SyntheticCTR.batch`` provides. The line-offset
index is built in one scan at construction; each batch is then a couple
of seeks, never a replay of the file prefix. The streaming ``reader()``
generator remains for purely-sequential consumers (O(1) memory, no
index).
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Sequence

import numpy as np

from repro.configs.base import RecsysConfig

NUM_INT = 13
NUM_CAT = 26


def _fingerprint(token: str, vocab: int) -> int:
    h = hashlib.md5(token.encode()).digest()
    return int.from_bytes(h[:8], "little") % vocab


def parse_lines(lines: Sequence[str], cfg: RecsysConfig
                ) -> Dict[str, np.ndarray]:
    b = len(lines)
    dense = np.zeros((b, NUM_INT), np.float32)
    cat = np.full((b, NUM_CAT, 1), -1, np.int32)
    label = np.zeros((b,), np.float32)
    for r, line in enumerate(lines):
        # \r too: binary-mode readers hand CRLF lines through untranslated,
        # and a trailing \r on C26 would silently remap its embedding id
        parts = line.rstrip("\r\n").split("\t")
        label[r] = float(parts[0])
        for i in range(NUM_INT):
            v = parts[1 + i]
            dense[r, i] = np.log1p(max(0.0, float(v))) if v else 0.0
        for c in range(NUM_CAT):
            v = parts[1 + NUM_INT + c]
            if v:
                cat[r, c, 0] = _fingerprint(
                    v, cfg.tables[c].vocab_size)
    return {"dense": dense, "cat": cat, "label": label}


class CriteoReader:
    """Seekable, stateless ``batch(step)`` view over a Criteo TSV file.

    Batch ``s`` covers absolute line indices ``[s*B, s*B + B)`` of the
    infinitely-looped file (index ``a`` maps to line ``a % num_lines``)
    — byte-identical to chunking the old looping generator's stream,
    but addressable by step in O(B) instead of replaying the prefix:
    deterministic failure-replay for criteo runs.
    """

    def __init__(self, path: str, cfg: RecsysConfig, batch_size: int):
        self.path = path
        self.cfg = cfg
        self.batch_size = batch_size
        self._offsets = self._index_lines(path)
        if len(self._offsets) == 0:
            raise ValueError(f"{path}: empty criteo file")

    @staticmethod
    def _index_lines(path: str) -> np.ndarray:
        """Byte offset of every line start, in one chunked scan with a
        vectorized newline search — 8 bytes/line resident and no
        Python-int list, so a Criteo-Terabyte-scale TSV indexes without
        a transient memory blow-up. A final line without a trailing
        newline counts, like ``for line in f`` does."""
        starts = [np.zeros(1, np.int64)]
        pos = 0
        with open(path, "rb") as f:
            while True:
                chunk = f.read(1 << 24)
                if not chunk:
                    break
                nl = np.flatnonzero(
                    np.frombuffer(chunk, np.uint8) == 0x0A)
                if len(nl):
                    starts.append(nl.astype(np.int64) + (pos + 1))
                pos += len(chunk)
        offs = np.concatenate(starts)
        # drop the bogus start at EOF (trailing newline) and, for an
        # empty file, the seed 0 itself
        return offs[offs < pos]

    @property
    def num_lines(self) -> int:
        return len(self._offsets)

    def read_lines(self, start: int, count: int) -> List[str]:
        """``count`` decoded lines from line index ``start``, wrapping
        past EOF back to line 0 (and again, if count > num_lines)."""
        lines: List[str] = []
        with open(self.path, "rb") as f:
            s = start % self.num_lines
            while count > 0:
                take = min(count, self.num_lines - s)
                f.seek(self._offsets[s])
                lines.extend(f.readline().decode("utf-8")
                             for _ in range(take))
                count -= take
                s = 0
        return lines

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        start = (step * self.batch_size) % self.num_lines
        return parse_lines(self.read_lines(start, self.batch_size),
                           self.cfg)


def reader(path: str, cfg: RecsysConfig, batch_size: int,
           *, loop: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    """Purely-sequential streaming reader: O(1) memory, first batch
    after ``batch_size`` lines — no offset index (a sequential consumer
    gains nothing from one; use :class:`CriteoReader` when you need
    seekable, replayable ``batch(step)`` access). ``loop=True`` streams
    forever, epoch boundaries crossing seamlessly; ``loop=False``
    yields one epoch, final partial batch included. Batch ``s`` of the
    looped stream is byte-identical to ``CriteoReader.batch(s)``."""
    buf: List[str] = []
    while True:
        with open(path) as f:
            for line in f:
                buf.append(line)
                if len(buf) == batch_size:
                    yield parse_lines(buf, cfg)
                    buf = []
        if not loop:
            if buf:
                yield parse_lines(buf, cfg)
            return


def write_synthetic_file(path: str, n: int, cfg: RecsysConfig,
                         seed: int = 0) -> None:
    """Emit a tiny Criteo-format file for tests."""
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for _ in range(n):
            label = rng.integers(0, 2)
            ints = [str(rng.integers(0, 1000)) if rng.random() > 0.1 else ""
                    for _ in range(NUM_INT)]
            cats = [f"{rng.integers(0, 2**32):08x}"
                    if rng.random() > 0.1 else "" for _ in range(NUM_CAT)]
            f.write("\t".join([str(label)] + ints + cats) + "\n")
