"""Criteo Terabyte / Kaggle format reader (the paper's benchmark dataset).

Format: TSV lines ``label \t I1..I13 \t C1..C26`` where I* are ints (may be
empty) and C* are 8-hex-digit category hashes (may be empty). Ids are
hashed into each table's vocab with a stable fingerprint, as HugeCTR's
data preprocessing does.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterator, List, Sequence

import numpy as np

from repro.configs.base import RecsysConfig

NUM_INT = 13
NUM_CAT = 26


def _fingerprint(token: str, vocab: int) -> int:
    h = hashlib.md5(token.encode()).digest()
    return int.from_bytes(h[:8], "little") % vocab


def parse_lines(lines: Sequence[str], cfg: RecsysConfig
                ) -> Dict[str, np.ndarray]:
    b = len(lines)
    dense = np.zeros((b, NUM_INT), np.float32)
    cat = np.full((b, NUM_CAT, 1), -1, np.int32)
    label = np.zeros((b,), np.float32)
    for r, line in enumerate(lines):
        parts = line.rstrip("\n").split("\t")
        label[r] = float(parts[0])
        for i in range(NUM_INT):
            v = parts[1 + i]
            dense[r, i] = np.log1p(max(0.0, float(v))) if v else 0.0
        for c in range(NUM_CAT):
            v = parts[1 + NUM_INT + c]
            if v:
                cat[r, c, 0] = _fingerprint(
                    v, cfg.tables[c].vocab_size)
    return {"dense": dense, "cat": cat, "label": label}


def reader(path: str, cfg: RecsysConfig, batch_size: int,
           *, loop: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    buf: List[str] = []
    while True:
        with open(path) as f:
            for line in f:
                buf.append(line)
                if len(buf) == batch_size:
                    yield parse_lines(buf, cfg)
                    buf = []
        if not loop:
            if buf:
                yield parse_lines(buf, cfg)
            return


def write_synthetic_file(path: str, n: int, cfg: RecsysConfig,
                         seed: int = 0) -> None:
    """Emit a tiny Criteo-format file for tests."""
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for _ in range(n):
            label = rng.integers(0, 2)
            ints = [str(rng.integers(0, 1000)) if rng.random() > 0.1 else ""
                    for _ in range(NUM_INT)]
            cats = [f"{rng.integers(0, 2**32):08x}"
                    if rng.random() > 0.1 else "" for _ in range(NUM_CAT)]
            f.write("\t".join([str(label)] + ints + cats) + "\n")
