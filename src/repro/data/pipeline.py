"""Host input pipeline: background prefetch + sharding-aware device_put.

HugeCTR overlaps its data reader with compute via CUDA streams; the JAX
analogue is a daemon thread filling a bounded queue while the device works,
plus ``jax.device_put`` with the batch's NamedSharding so each host only
materializes its addressable shards.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Prefetcher:

    def __init__(self, source: Iterator, depth: int = 2,
                 transform: Optional[Callable] = None):
        self._source = source
        self._transform = transform
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._source:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()


def batch_shardings(mesh: Mesh, dp_axes=None) -> Dict[str, NamedSharding]:
    dp = dp_axes or tuple(a for a in mesh.axis_names if a != "model")
    return {
        "dense": NamedSharding(mesh, P(dp, None)),
        "cat": NamedSharding(mesh, P(dp, None, None)),
        "label": NamedSharding(mesh, P(dp)),
    }


def put_batch(batch: Dict[str, np.ndarray], mesh: Mesh) -> Dict:
    sh = batch_shardings(mesh)
    return {k: jax.device_put(v, sh[k]) for k, v in batch.items()}
