"""Synthetic CTR data with Zipfian categorical features.

Ids are drawn frequency-sorted (rank 0 = most frequent), matching Criteo's
standard preprocessing — this is what makes ``id < hot_rows`` a valid hot
test for the hybrid embedding (DESIGN.md §4).

Generation is **stateless**: ``batch(step)`` is a pure function of
``(seed, step)`` so a fault-tolerant trainer can replay any step after
restore without data-pipeline checkpoints.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.configs.base import RecsysConfig


class SyntheticCTR:

    def __init__(self, cfg: RecsysConfig, batch_size: int, *,
                 seed: int = 0, zipf_a: float = 1.1):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seed = seed
        self.zipf_a = zipf_a
        #: cat column layout covers EVERY embedding group (primary tables
        #: first, then each extra group's, in declaration order)
        self.tables = tuple(getattr(cfg, "all_tables", cfg.tables))
        self.max_hot = max(t.hotness for t in self.tables)
        # planted logistic model so training has signal
        rng = np.random.default_rng(seed + 7777)
        self._w_dense = rng.normal(size=cfg.num_dense_features) * 0.5
        self._w_cat = [rng.normal(size=t.vocab_size) * 0.5
                       for t in self.tables]

    def _zipf_ids(self, rng, vocab: int, size) -> np.ndarray:
        """Frequency-sorted Zipf draw truncated to [0, vocab)."""
        u = rng.random(size)
        # inverse-CDF of a bounded-Pareto (continuous Zipf) on [1, V+1)
        a = self.zipf_a
        x = (u * ((vocab + 1.0) ** (1 - a) - 1.0) + 1.0) ** (1 / (1 - a))
        return np.clip(np.floor(x).astype(np.int64) - 1, 0, vocab - 1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        cfg = self.cfg
        b, t, h = self.batch_size, len(self.tables), self.max_hot
        cat = np.full((b, t, h), -1, np.int32)
        score = np.zeros(b)
        for i, tab in enumerate(self.tables):
            ids = self._zipf_ids(rng, tab.vocab_size, (b, tab.hotness))
            cat[:, i, :tab.hotness] = ids
            score += self._w_cat[i][ids].sum(axis=1) / tab.hotness
        dense = rng.lognormal(size=(b, cfg.num_dense_features)) \
            .astype(np.float32)
        dense = np.log1p(dense)  # criteo-style transform
        score += dense @ self._w_dense
        prob = 1.0 / (1.0 + np.exp(-(score - score.mean())))
        label = (rng.random(b) < prob).astype(np.float32)
        return {"dense": dense, "cat": cat, "label": label}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
