"""Keras-like high-level API (paper §2).

HugeCTR ships a Python API whose *look & feel* follows Keras so that
"the tedious task of deploying individual training and inference jobs in
an optimized manner on a specific hardware topology can be delegated" to
the framework. Same idea here: declare tables + dense layers, call
``compile()`` / ``fit()`` / ``predict()`` / ``deploy()`` — mesh
construction, placement planning, sharding, jit, checkpoints all happen
inside.

    from repro.api import Model, SparseEmbedding, Dense

    m = Model([
        SparseEmbedding(vocab_sizes=[1000, 500, 200], dim=16, hotness=2),
        Dense([64, 32, 1]),
    ])
    m.compile(optimizer="adamw", lr=1e-2)
    hist = m.fit(data_fn, steps=100, ckpt_dir="/tmp/ckpt")
    preds = m.predict(batch)
    server = m.deploy("/tmp/pdb")          # -> HPS-backed server
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    EmbeddingTableConfig, RecsysConfig, TrainConfig,
)
from repro.launch.mesh import make_production_mesh, make_test_mesh


@dataclasses.dataclass
class SparseEmbedding:
    """Declarative embedding layer: one table per categorical feature.

    ``strategy="auto"`` delegates placement (localized / distributed /
    hybrid / replicated) to the planner, per table.
    """
    vocab_sizes: Sequence[int]
    dim: int
    hotness: int = 1
    combiner: str = "sum"
    strategy: str = "auto"
    hot_fraction: float = 0.05

    def to_tables(self):
        return tuple(
            EmbeddingTableConfig(f"f{i}", v, self.dim,
                                 hotness=self.hotness,
                                 combiner=self.combiner,
                                 strategy=self.strategy,
                                 hot_fraction=self.hot_fraction)
            for i, v in enumerate(self.vocab_sizes))


@dataclasses.dataclass
class Dense:
    """The dense tower (MLP over [dense_features; flattened embeddings])."""
    units: Sequence[int]
    num_dense_features: int = 13


@dataclasses.dataclass
class Interaction:
    """DLRM-style pairwise-dot interaction between embedding vectors."""
    bottom_mlp: Sequence[int] = (64, 16)
    top_mlp: Sequence[int] = (64, 32, 1)
    num_dense_features: int = 13


class Model:

    def __init__(self, layers: List, *, name: str = "model",
                 mesh=None):
        self.name = name
        emb = [l for l in layers if isinstance(l, SparseEmbedding)]
        if len(emb) != 1:
            raise ValueError("exactly one SparseEmbedding layer required")
        self._emb = emb[0]
        dense = [l for l in layers if isinstance(l, (Dense, Interaction))]
        if len(dense) != 1:
            raise ValueError("exactly one Dense or Interaction layer "
                             "required")
        self._dense = dense[0]
        n_dev = len(jax.devices())
        self.mesh = mesh or (make_test_mesh((n_dev, 1)) if n_dev < 256
                             else make_production_mesh())
        self._model = None
        self._params = None
        self._opt_state = None
        self._tcfg: Optional[TrainConfig] = None
        self._trainer = None

    # -- build ----------------------------------------------------------------

    def _build_cfg(self, batch: int) -> RecsysConfig:
        tables = self._emb.to_tables()
        if isinstance(self._dense, Interaction):
            bottom = tuple(self._dense.bottom_mlp)
            if bottom[-1] != self._emb.dim:
                bottom = bottom + (self._emb.dim,)
            return RecsysConfig(
                name=self.name, model="dlrm", tables=tables,
                num_dense_features=self._dense.num_dense_features,
                bottom_mlp=bottom, top_mlp=tuple(self._dense.top_mlp),
                embedding_dim=self._emb.dim)
        # plain Dense tower = DCN with zero cross layers (no wide branch,
        # so the deployed server needs exactly one HPS)
        units = tuple(self._dense.units)
        if units[-1] == 1:
            units = units[:-1] or (16,)
        return RecsysConfig(
            name=self.name, model="dcn", tables=tables,
            num_dense_features=self._dense.num_dense_features,
            bottom_mlp=(), top_mlp=units, embedding_dim=self._emb.dim,
            num_cross_layers=0)

    def compile(self, *, optimizer: str = "adamw", lr: float = 1e-3,
                sparse_optimizer: str = "rowwise_adagrad",
                batch_size: int = 256, mode: str = "gspmd"):
        from repro.models.recsys.model import RecsysModel
        self._tcfg = TrainConfig(learning_rate=lr,
                                 dense_optimizer=optimizer,
                                 sparse_optimizer=sparse_optimizer)
        self.cfg = self._build_cfg(batch_size)
        self.batch_size = batch_size
        self._mode = mode
        with self.mesh:
            self._model = RecsysModel(self.cfg, self.mesh,
                                      global_batch=batch_size)
        return self

    # -- train ------------------------------------------------------------------

    def fit(self, data_fn: Callable[[int], Dict], steps: int, *,
            ckpt_dir: Optional[str] = None, log_every: int = 0,
            seed: int = 0) -> List[Dict]:
        """``data_fn(step) -> {"dense", "cat", "label"}`` host batches."""
        if self._model is None:
            raise RuntimeError("call compile() first")
        from repro.train.trainer import Trainer
        with self.mesh:
            self._trainer = Trainer(self._model, self._tcfg, self.mesh,
                                    data_fn, ckpt_dir=ckpt_dir,
                                    mode=self._mode)
            out = self._trainer.train(steps, seed=seed,
                                      log_every=log_every)
        self._params = out["params"]
        self._opt_state = out["opt_state"]
        return out["history"]

    # -- inference ----------------------------------------------------------------

    def predict(self, batch: Dict) -> np.ndarray:
        if self._params is None:
            raise RuntimeError("fit() (or load) before predict()")
        with self.mesh:
            logits = jax.jit(self._model.apply)(
                self._params,
                {k: jnp.asarray(v) for k, v in batch.items()
                 if k in ("dense", "cat")})
        return np.asarray(jax.nn.sigmoid(logits))

    def deploy(self, pdb_root: str, *, cache_capacity: int = 4096):
        """Export to the HPS and return a ready InferenceServer."""
        from repro.core.hps.hps import HPS
        from repro.core.hps.persistent_db import PersistentDB
        from repro.serve.server import InferenceServer, deploy_from_training
        pdb = PersistentDB(pdb_root)
        deploy_from_training(self._model, self._params, pdb, self.name)
        hps = HPS(self.name, self.cfg.tables, pdb,
                  cache_capacity=cache_capacity)
        dense = {k: v for k, v in self._params.items()
                 if k not in ("embedding",)}
        wide_hps = None
        return InferenceServer(self._model, dense, hps, wide_hps=wide_hps)

    # -- persistence -----------------------------------------------------------------

    def save(self, directory: str, step: int = 0):
        from repro.train import checkpoint as ck
        tree = {"params": self._trainer._export(self._params)
                if self._trainer else self._params}
        ck.save(directory, step, tree)

    @property
    def params(self):
        return self._params
