"""HugeCTR-style declarative graph API (paper §2).

HugeCTR's Python API is a *model graph*, not a two-slot facade: a
``Solver`` carries the run-level knobs, ``DataReaderParams`` describes
the input source, and the network is a list of named layers wired by
``bottom_names``/``top_names`` — serialized to JSON and consumed
verbatim by the inference side. Same shape here:

    from repro.api import (CreateSolver, DataReaderParams, DenseLayer,
                           Input, Model, SparseEmbedding)

    solver = CreateSolver(batch_size=256, lr=1e-2)
    reader = DataReaderParams(source="synthetic", num_dense_features=13)
    m = Model(solver, reader, name="dlrm-demo")
    m.add(Input(dense_dim=13))
    m.add(SparseEmbedding(vocab_sizes=[1000, 500, 200], dim=16,
                          top_name="emb"))
    m.add(DenseLayer("mlp", ["dense"], ["bot"], units=(32, 16),
                     final_activation=True))
    m.add(DenseLayer("dot_interaction", ["bot", "emb"], ["inter"]))
    m.add(DenseLayer("concat", ["bot", "inter"], ["top_in"]))
    m.add(DenseLayer("mlp", ["top_in"], ["logit"], units=(32, 1)))
    m.add(DenseLayer("sigmoid", ["logit"], ["prob"]))
    m.compile()
    m.summary()
    m.fit(steps=100)                       # reader-driven data
    m.graph_to_json("graph.json")          # round-trip: Model.from_json
    m.save("ckpt_dir")                     # graph + weights; Model.load
    server = m.deploy("deploy_dir")        # writes ps.json bundle, too

**Generic compilation.** ``compile()`` does NOT pattern-match a menu of
recipes: the lowering pass validates the ``DenseLayer`` DAG (unknown
tensors, duplicate names, cycles, arity, shape agreement, a single
terminal, no unused layers), topologically sorts it (layers may be
added in any order), infers every tensor's shape, and emits a
``DenseGraphProgram`` (``models/recsys/dense_graph.py``) — per-layer
parameter init plus one jitted apply that the training and serving
stacks execute for ANY valid graph. A graph that happens to be one of
the four paper recipes lowers to that recipe's canonical
``RecsysConfig`` (``model="dlrm"|"dcn"|"deepfm"|"wdl"``, bit-exact with
the registry configs, paper semantics preserved — e.g. the WDL wide
head pools the wide branch with fixed weight 1); every other graph
lowers to ``model="graph"`` with the DAG embedded in the config, and
trains / round-trips / deploys / exports with zero per-architecture
code.

**Layer vocabulary and shape rules.** Shapes are written per sample
(the batch axis is implicit): ``[n]`` is a 2-D feature block,
``[T, D]`` a 3-D pooled-embedding block, ``[]`` a logit column.
Inputs: the ``Input``'s dense tensor is ``[dense_dim]``; each
``SparseEmbedding`` group's top is ``[T, D]`` (the dim-1 wide group is
``[T, 1]``). 3-D blocks flatten to ``[T*D]`` wherever a 2-D view is
needed.

====================  =====================================================
``mlp``               1+ bottoms, flattened + concatenated -> ``[units[-1]]``;
                      ``units`` per layer, ``final_activation`` keeps the
                      last ReLU.
``cross``             1 bottom ``[n]`` -> ``[n]``; DCN cross net,
                      ``num_layers`` deep.
``dot_interaction``   ``[D]`` + ``[T, D]`` -> ``[(T+1)T/2]``; DLRM pairwise
                      dots (the 2-D bottom must end at the embedding dim).
``fm``                ``[n]`` + ``[T, 1]`` + ``[T, D]`` (any order) ->
                      ``[]``; factorization-machine first+second order.
``concat``            1+ bottoms, flattened -> ``[sum of dims]``.
``add``               2+ bottoms of identical shape -> same (elementwise).
``multiply``          2+ bottoms of identical shape -> same (elementwise).
``relu``              1 bottom -> same shape.
``slice``             1 bottom ``[n]`` -> ``[stop-start]`` (feature axis).
``reduce_sum``        1 bottom -> ``[]`` (sums all non-batch axes).
``sigmoid``           terminal only: sums its logit-shaped (``[]`` or
                      ``[1]``) bottoms and emits the probability.
====================  =====================================================

The graph must end in exactly ONE terminal tensor (produced, never
consumed): a ``sigmoid`` layer, or a logit-shaped tensor.

**N-group embeddings.** A model may declare ANY number of
``SparseEmbedding`` groups, each with its own dim / vocab sizes /
hotness — the NeuMF/two-tower shape with separate user and item
embedding dims. The first declared group is the primary collection;
every further group lowers to its own ``EmbeddingCollection`` (param
key ``embedding@<top_name>``), its own column span in the ``cat``
input (columns follow declaration order: primary tables first, then
each group's), and its own HPS table set at deploy time. Table names
must be globally unique; a group without explicit ``table_names``
defaults to ``<top_name>_f<i>`` (the primary keeps ``f<i>``). One
special case is kept for the paper recipes: exactly two groups where
one is the dim-1 exact twin of the other (same vocab sizes,
``combiner="sum"``) lower as deep + wide branch — WDL/DeepFM and any
novel graph wanting a first-order term.

**Model parallelism.** ``Solver`` carries the mesh intent and
``fit()`` honors it end to end: ``mesh_shape=(r, c)`` lays the visible
devices out as a ``("data", "model")`` mesh (validated up front
against the visible device count), embeddings shard over the mesh per
the placement planner while the dense net stays data-parallel, and the
sharded train step runs under either ``mode="gspmd"`` (XLA inserts the
collectives) or ``mode="manual"`` (explicit psum, compressed gradient
all-reduce via ``grad_allreduce_dtype``). ``comm`` picks the embedding
exchange per collection: ``"allgather_rs"``, ``"all_to_all"``, or
``"auto"`` (the default — all-to-all only for groups of large one-hot
tables, threshold ``a2a_threshold``; pooled or small tables keep
allgather + reduce-scatter). Checkpoints store mesh-independent
logical arrays, so ``save()`` on one mesh and ``load()`` on another
just works.

``graph_to_json`` embeds a hash of the lowered config;
``Model.from_json`` re-lowers and verifies it. ``deploy(directory)``
writes a relocatable serving bundle — ``pdb/`` (all tables, wide twins
included), ``graph.json``, ``dense.npz`` and a ps.json-style
``HPSConfig`` — and ``launch/serve.py`` reconstructs the
``HPS`` + ``InferenceServer`` from that bundle alone, novel graphs
included, no Python object from training in hand.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    EmbeddingTableConfig, EnsembleConfig, ETCParams, HPSConfig,
    RecsysConfig, SparseGroupConfig, TrainConfig,
    ensemble_config_to_dict, hps_config_to_dict, recsys_config_hash,
)

from repro.models.recsys.dense_graph import (
    GraphError, RESERVED_NAMES, compile_layers, graph_spec,
    spec_from_layer,
)

GRAPH_FORMAT = "repro-graph-v1"
PS_FORMAT = "repro-ps-v1"


# ---------------------------------------------------------------------------
# Run-level declarations
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Solver:
    """Run-level knobs (HugeCTR's ``CreateSolver``): batch, mesh, mode,
    and both optimizers — everything ``compile()`` used to take as
    keyword soup."""
    batch_size: int = 256
    lr: float = 1e-3
    optimizer: str = "adamw"                  # dense tower optimizer
    sparse_optimizer: str = "rowwise_adagrad"  # embedding optimizer
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    grad_allreduce_dtype: str = "f32"
    mixed_precision: bool = True
    mode: str = "gspmd"                       # "gspmd" | "manual"
    #: None = size the mesh to the visible devices; (r, c) = test mesh
    mesh_shape: Optional[Tuple[int, ...]] = None
    #: embedding exchange per collection: "auto" picks all_to_all for
    #: groups of large one-hot tables (>= a2a_threshold rows) and
    #: allgather_rs otherwise; or pin "allgather_rs" / "all_to_all"
    comm: str = "auto"
    a2a_threshold: int = 65536
    ckpt_interval: int = 50
    seed: int = 0
    #: ETC-staged training (HugeCTR's Embedding Training Cache): set to
    #: ``ETCParams(cache_rows=..., ps="staged"|"cached", passes=N)`` and
    #: ``fit()`` trains through a fixed-capacity device row cache backed
    #: by a parameter server instead of full in-device tables —
    #: ``cache_rows`` bounds device rows per table, ``ps`` picks the
    #: durable tier ("cached" needs ``ps_root``, survives restarts and
    #: fsyncs on flush), ``passes`` splits the run into keyset-staged
    #: passes whose boundaries flush the cache and (via
    #: ``repro.online``) publish versioned updates to live servers.
    #: None (default) keeps the in-memory trainer.
    etc: Optional[ETCParams] = None

    def __post_init__(self):
        if self.etc is not None and not isinstance(self.etc, ETCParams):
            if not isinstance(self.etc, dict):
                raise GraphError(
                    f"Solver.etc must be an ETCParams (or its dict "
                    f"form), got {type(self.etc).__name__}")
            try:                   # JSON round-trip: Solver(**d["solver"])
                self.etc = ETCParams(**self.etc)
            except (TypeError, ValueError) as e:
                raise GraphError(f"Solver.etc: {e}")
        if self.mode not in ("gspmd", "manual"):
            raise GraphError(
                f"Solver.mode must be 'gspmd' or 'manual', got "
                f"{self.mode!r}")
        if self.comm not in ("auto", "allgather_rs", "all_to_all"):
            raise GraphError(
                f"Solver.comm must be 'auto', 'allgather_rs' or "
                f"'all_to_all', got {self.comm!r}")
        if self.mesh_shape is not None:
            shape = tuple(self.mesh_shape)
            if not shape or any(not isinstance(s, int) or
                                isinstance(s, bool) or s <= 0
                                for s in shape):
                raise GraphError(
                    f"Solver.mesh_shape must be a non-empty tuple of "
                    f"positive ints, got {self.mesh_shape!r}")
            want = 1
            for s in shape:
                want *= s
            visible = len(jax.devices())
            if want > visible:
                raise GraphError(
                    f"Solver.mesh_shape={shape} asks for {want} devices "
                    f"but only {visible} are visible; shrink the mesh "
                    f"or force host devices with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={want} "
                    "(set before jax initializes)")
            self.mesh_shape = shape

    def to_train_config(self) -> TrainConfig:
        return TrainConfig(
            learning_rate=self.lr, dense_optimizer=self.optimizer,
            sparse_optimizer=self.sparse_optimizer,
            weight_decay=self.weight_decay, grad_clip=self.grad_clip,
            mixed_precision=self.mixed_precision,
            grad_allreduce_dtype=self.grad_allreduce_dtype)


def CreateSolver(**kwargs) -> Solver:  # noqa: N802 — HugeCTR spelling
    return Solver(**kwargs)


@dataclasses.dataclass
class DataReaderParams:
    """Input source + feature spec. ``synthetic`` draws the stateless
    Zipf CTR stream; ``criteo`` reads the TSV format at ``path``."""
    source: str = "synthetic"
    num_dense_features: int = 13
    path: Optional[str] = None
    seed: int = 0
    zipf_a: float = 1.1

    def __post_init__(self):
        if self.source not in ("synthetic", "criteo"):
            raise GraphError(f"unknown reader source {self.source!r}")


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Input:
    """Declares the named input tensors every other layer wires to."""
    dense_dim: int
    dense_name: str = "dense"
    sparse_name: str = "cat"
    label_name: str = "label"


@dataclasses.dataclass
class SparseEmbedding:
    """One embedding group: a set of tables sharing dim / combiner /
    placement strategy. Repeatable — WDL/DeepFM add a second, dim-1
    group for the wide branch."""
    vocab_sizes: Sequence[int]
    dim: int
    top_name: str = "emb"
    bottom_name: str = "cat"
    #: ids per sample, scalar or per-table
    hotness: Union[int, Sequence[int]] = 1
    combiner: str = "sum"
    strategy: str = "auto"
    hot_fraction: float = 0.05
    table_names: Optional[Sequence[str]] = None

    def __post_init__(self):
        self.vocab_sizes = tuple(int(v) for v in self.vocab_sizes)
        if not isinstance(self.hotness, int):
            self.hotness = tuple(int(h) for h in self.hotness)
        if self.table_names is not None:
            self.table_names = tuple(self.table_names)
            if len(self.table_names) != len(self.vocab_sizes):
                raise GraphError(
                    f"{len(self.table_names)} table_names for "
                    f"{len(self.vocab_sizes)} vocab_sizes")

    def to_tables(self, *, default_prefix: str = ""
                  ) -> Tuple[EmbeddingTableConfig, ...]:
        names = self.table_names or tuple(
            f"{default_prefix}f{i}" for i in range(len(self.vocab_sizes)))
        hot = self.hotness if not isinstance(self.hotness, int) else \
            (self.hotness,) * len(self.vocab_sizes)
        return tuple(
            EmbeddingTableConfig(names[i], v, self.dim, hotness=hot[i],
                                 combiner=self.combiner,
                                 strategy=self.strategy,
                                 hot_fraction=self.hot_fraction)
            for i, v in enumerate(self.vocab_sizes))


DENSE_LAYER_TYPES = ("mlp", "cross", "dot_interaction", "fm", "concat",
                     "sigmoid", "add", "multiply", "relu", "slice",
                     "reduce_sum")


@dataclasses.dataclass
class DenseLayer:
    """One named dense layer, wired by tensor names.

    The full vocabulary and its shape rules are documented in the module
    docstring. Highlights:

    ``mlp``              — MLP over the (implicitly concatenated)
                           bottoms; ``units`` per layer,
                           ``final_activation`` keeps the last ReLU.
    ``cross``            — DCN cross net, ``num_layers`` deep.
    ``dot_interaction``  — DLRM pairwise dots over
                           ``[bottom_mlp_out, emb]``.
    ``fm``               — factorization-machine first+second order term
                           over ``[dense, wide, emb]``.
    ``concat``           — multi-input feature concatenation (3-D
                           embeddings flatten).
    ``add`` / ``multiply`` — elementwise over same-shaped bottoms.
    ``relu``             — elementwise activation.
    ``slice``            — ``[start:stop]`` on the feature axis.
    ``reduce_sum``       — sums all non-batch axes to a logit column.
    ``sigmoid``          — terminal: sums its bottom logits, emits the
                           probability.
    """
    type: str
    bottom_names: Sequence[str]
    top_names: Sequence[str]
    units: Sequence[int] = ()
    num_layers: int = 0                 # cross only
    final_activation: bool = False      # mlp only
    start: int = 0                      # slice only
    stop: int = 0                       # slice only

    def __post_init__(self):
        if self.type not in DENSE_LAYER_TYPES:
            raise GraphError(
                f"unknown DenseLayer type {self.type!r}; expected one "
                f"of {DENSE_LAYER_TYPES}")
        self.bottom_names = tuple(self.bottom_names)
        self.top_names = tuple(self.top_names)
        self.units = tuple(int(u) for u in self.units)
        if len(self.top_names) != 1:
            raise GraphError(
                f"DenseLayer({self.type}) must produce exactly one "
                f"output, got top_names={self.top_names}")

    @property
    def top(self) -> str:
        return self.top_names[0]


# ---------------------------------------------------------------------------
# Lowering: layer graph -> RecsysConfig (generic compile + recognition)
# ---------------------------------------------------------------------------

def _check_embeddings(inp: Input, embs: List[SparseEmbedding]) -> None:
    produced = {inp.dense_name}
    for e in embs:
        if e.bottom_name != inp.sparse_name:
            raise GraphError(
                f"SparseEmbedding {e.top_name!r} reads "
                f"{e.bottom_name!r} but the Input's sparse tensor is "
                f"{inp.sparse_name!r}")
        if e.top_name in produced:
            raise GraphError(f"duplicate tensor name {e.top_name!r}")
        if e.top_name in RESERVED_NAMES or \
                e.top_name.startswith("embedding@"):
            raise GraphError(
                f"SparseEmbedding top_name {e.top_name!r} is reserved "
                "for the embedding parameter groups")
        produced.add(e.top_name)


def _split_embeddings(embs: List[SparseEmbedding]
                      ) -> Tuple[SparseEmbedding,
                                 Optional[SparseEmbedding],
                                 List[SparseEmbedding]]:
    """Split declared groups into (deep, wide, extras).

    The one shape the paper recipes rely on is preserved: exactly TWO
    groups where one is the dim-1 exact twin of the other (same vocab
    sizes, ``combiner="sum"``) classify as deep + wide branch. Every
    other combination lowers as N independent groups: the first
    declared is the primary collection, the rest are extras with their
    own dims, collections and HPS table sets.
    """
    if len(embs) == 1:
        return embs[0], None, []
    if len(embs) == 2:
        wides = [e for e in embs if e.dim == 1]
        if len(wides) == 1:
            wide = wides[0]
            deep = next(e for e in embs if e is not wide)
            if wide.vocab_sizes == deep.vocab_sizes and \
                    wide.combiner == "sum":
                return deep, wide, []
    return embs[0], None, list(embs[1:])


# -- canonical-recipe recognition -------------------------------------------
#
# Recognition is NOT required for execution (any valid DAG compiles);
# it only maps the four paper recipes onto their canonical RecsysConfigs
# so they stay bit-exact with the registry entries, keep their
# historical parameter names, and keep the paper's semantics (e.g. the
# WDL wide head pools the wide branch with fixed weight 1). A graph
# that misses a canonical shape by any detail simply lowers generically.

def _find(layers: List[DenseLayer], type_: str,
          bottoms: Optional[Tuple[str, ...]] = None) -> List[DenseLayer]:
    return [l for l in layers if l.type == type_ and
            (bottoms is None or tuple(l.bottom_names) == tuple(bottoms))]


def _take_sigmoid(layers: List[DenseLayer], logits: Tuple[str, ...],
                  used: List[DenseLayer], *, required: bool) -> bool:
    sigs = _find(layers, "sigmoid")
    if len(sigs) > 1:
        return False
    if not sigs:
        return not required
    # set AND length: a duplicated bottom (e.g. ['logit', 'logit'])
    # means 2x-logit semantics under the generic executor, so it must
    # NOT classify as the canonical recipe
    if len(sigs[0].bottom_names) != len(logits) or \
            set(sigs[0].bottom_names) != set(logits):
        return False
    used.append(sigs[0])
    return True


def _classify_dlrm(name, inp, deep, layers):
    inters = _find(layers, "dot_interaction")
    if len(inters) != 1:
        return None
    inter = inters[0]
    if len(inter.bottom_names) != 2 or \
            inter.bottom_names[1] != deep.top_name:
        return None
    bots = [l for l in layers if l.top == inter.bottom_names[0]]
    if len(bots) != 1:
        return None
    bot = bots[0]
    if bot.type != "mlp" or tuple(bot.bottom_names) != (inp.dense_name,) \
            or not bot.final_activation or not bot.units \
            or bot.units[-1] != deep.dim:
        return None
    used = [bot, inter]
    top_bottoms = (bot.top, inter.top)
    cats = _find(layers, "concat", top_bottoms)
    if cats:
        if len(cats) != 1:
            return None
        used.append(cats[0])
        top_bottoms = (cats[0].top,)
    tops = [l for l in layers if l.type == "mlp" and l is not bot]
    if len(tops) != 1:
        return None
    top = tops[0]
    if tuple(top.bottom_names) != top_bottoms or not top.units or \
            top.units[-1] != 1 or top.final_activation:
        return None
    used.append(top)
    if not _take_sigmoid(layers, (top.top,), used, required=False):
        return None
    if len(used) != len(layers):
        return None
    return RecsysConfig(
        name=name, model="dlrm", tables=deep.to_tables(),
        num_dense_features=inp.dense_dim, bottom_mlp=bot.units,
        top_mlp=top.units, embedding_dim=deep.dim)


def _classify_dcn(name, inp, deep, layers):
    flats = _find(layers, "concat", (inp.dense_name, deep.top_name))
    if len(flats) != 1:
        return None
    flat = flats[0]
    used = [flat]
    crosses = _find(layers, "cross")
    if len(crosses) > 1:
        return None
    crossed = flat.top
    cross = crosses[0] if crosses else None
    if cross is not None:
        if tuple(cross.bottom_names) != (flat.top,):
            return None
        crossed = cross.top
        used.append(cross)
    mlps = [l for l in layers if l.type == "mlp"]
    deeps = [l for l in mlps if tuple(l.bottom_names) == (flat.top,)]
    if len(deeps) != 1:
        return None
    deep_mlp = deeps[0]
    if deep_mlp.final_activation or not deep_mlp.units:
        return None
    used.append(deep_mlp)
    boths = _find(layers, "concat", (crossed, deep_mlp.top))
    if len(boths) != 1:
        return None
    used.append(boths[0])
    combines = [l for l in mlps
                if tuple(l.bottom_names) == (boths[0].top,)]
    if len(combines) != 1:
        return None
    combine = combines[0]
    if combine.units != (1,) or combine.final_activation:
        return None
    used.append(combine)
    if not _take_sigmoid(layers, (combine.top,), used, required=False):
        return None
    if len(used) != len(layers):
        return None
    return RecsysConfig(
        name=name, model="dcn", tables=deep.to_tables(),
        num_dense_features=inp.dense_dim, bottom_mlp=(),
        top_mlp=deep_mlp.units, embedding_dim=deep.dim,
        num_cross_layers=cross.num_layers if cross is not None else 0)


def _classify_flat_deep(inp, deep, layers):
    """The concat + 1-logit deep-tower pair DeepFM and WDL share."""
    flats = _find(layers, "concat", (inp.dense_name, deep.top_name))
    if len(flats) != 1:
        return None
    flat = flats[0]
    deeps = [l for l in layers if l.type == "mlp"
             and tuple(l.bottom_names) == (flat.top,)]
    if len(deeps) != 1:
        return None
    deep_mlp = deeps[0]
    if deep_mlp.final_activation or not deep_mlp.units or \
            deep_mlp.units[-1] != 1:
        return None
    return flat, deep_mlp


def _classify_deepfm(name, inp, deep, wide, layers):
    pair = _classify_flat_deep(inp, deep, layers)
    if pair is None:
        return None
    flat, deep_mlp = pair
    fms = _find(layers, "fm")
    if len(fms) != 1:
        return None
    fm = fms[0]
    if len(fm.bottom_names) != 3 or set(fm.bottom_names) != \
            {inp.dense_name, wide.top_name, deep.top_name}:
        return None
    used = [flat, deep_mlp, fm]
    if not _take_sigmoid(layers, (fm.top, deep_mlp.top), used,
                         required=True):
        return None
    if len(used) != len(layers):
        return None
    return RecsysConfig(
        name=name, model="deepfm", tables=deep.to_tables(),
        num_dense_features=inp.dense_dim, bottom_mlp=(),
        top_mlp=deep_mlp.units[:-1], embedding_dim=deep.dim)


def _classify_wdl(name, inp, deep, wide, layers):
    pair = _classify_flat_deep(inp, deep, layers)
    if pair is None:
        return None
    flat, deep_mlp = pair
    heads = [l for l in layers if l.type == "mlp"
             and set(l.bottom_names) == {inp.dense_name, wide.top_name}]
    if len(heads) != 1:
        return None
    head = heads[0]
    if head.units != (1,) or head.final_activation:
        return None
    used = [flat, deep_mlp, head]
    if not _take_sigmoid(layers, (head.top, deep_mlp.top), used,
                         required=True):
        return None
    if len(used) != len(layers):
        return None
    return RecsysConfig(
        name=name, model="wdl", tables=deep.to_tables(),
        num_dense_features=inp.dense_dim, bottom_mlp=(),
        top_mlp=deep_mlp.units[:-1], embedding_dim=deep.dim)


def _classify_canonical(name, inp, deep, wide, layers):
    types = {l.type for l in layers}
    if types - {"mlp", "cross", "dot_interaction", "fm", "concat",
                "sigmoid"}:
        return None                     # extended vocabulary -> generic
    if "dot_interaction" in types:
        if wide is not None:
            return None
        return _classify_dlrm(name, inp, deep, layers)
    if "fm" in types:
        if wide is None:
            return None
        return _classify_deepfm(name, inp, deep, wide, layers)
    if wide is not None:
        return _classify_wdl(name, inp, deep, wide, layers)
    return _classify_dcn(name, inp, deep, layers)


def lower_graph(name: str, inp: Optional[Input],
                embs: List[SparseEmbedding],
                layers: List[DenseLayer]) -> RecsysConfig:
    """Compile the layer graph: validate the DAG (wiring, shapes, single
    terminal), then lower it — onto the canonical config when it IS one
    of the four paper recipes, onto a generic ``model="graph"`` config
    (DAG embedded) for everything else. :class:`GraphError` names the
    offending layer/tensor on any invalid graph."""
    if inp is None:
        raise GraphError("the graph needs an Input layer")
    if not embs:
        raise GraphError("the graph needs at least one SparseEmbedding")
    _check_embeddings(inp, embs)
    deep, wide, extras = _split_embeddings(embs)
    specs = [spec_from_layer(l) for l in layers]
    extra_embs = {e.top_name: (len(e.vocab_sizes), e.dim)
                  for e in extras}
    # the generic compile IS the validation: every graph must pass it
    compile_layers(
        specs, dense_name=inp.dense_name, num_dense=inp.dense_dim,
        emb_name=deep.top_name, num_tables=len(deep.vocab_sizes),
        emb_dim=deep.dim,
        wide_name=wide.top_name if wide is not None else None,
        extra_embs=extra_embs)
    if not extras:
        cfg = _classify_canonical(name, inp, deep, wide, layers)
        if cfg is not None:
            return cfg
    extra_groups = tuple(
        SparseGroupConfig(
            name=e.top_name,
            tables=e.to_tables(default_prefix=f"{e.top_name}_"),
            dim=e.dim)
        for e in extras)
    all_names = [t.name for t in deep.to_tables()] \
        + [t.name for g in extra_groups for t in g.tables]
    seen = set()
    for tn in all_names:
        if tn in seen:
            raise GraphError(
                f"table name {tn!r} is used by more than one "
                "SparseEmbedding group; table names must be globally "
                "unique (set table_names explicitly)")
        seen.add(tn)
    return RecsysConfig(
        name=name, model="graph", tables=deep.to_tables(),
        num_dense_features=inp.dense_dim, bottom_mlp=(), top_mlp=(),
        embedding_dim=deep.dim,
        dense_graph=graph_spec(
            inp.dense_name, deep.top_name,
            wide.top_name if wide is not None else None, specs,
            extras=tuple(e.top_name for e in extras)),
        wide_branch=wide is not None,
        extra_groups=extra_groups)


# ---------------------------------------------------------------------------
# The model graph
# ---------------------------------------------------------------------------

def _auto_mesh(mesh_shape: Optional[Tuple[int, ...]]):
    from repro.launch.mesh import make_production_mesh, make_test_mesh
    if mesh_shape is not None:
        return make_test_mesh(tuple(mesh_shape))
    n_dev = len(jax.devices())
    return make_test_mesh((n_dev, 1)) if n_dev < 256 \
        else make_production_mesh()


def _validate_mesh_fit(cfg: RecsysConfig, mesh, batch_size: int) -> None:
    """Up-front mesh / batch / table divisibility validation.

    Everything checked here used to surface as an inscrutable shape
    error deep inside ``shard_map`` on the first ``fit()`` step; now it
    raises a :class:`GraphError` at ``compile()`` naming the offending
    axis or table group.
    """
    axes = tuple(mesh.axis_names)
    model_axis = "model" if "model" in axes else axes[-1]
    dp_axes = tuple(a for a in axes if a != model_axis)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    n_dev = int(np.prod(mesh.devices.shape))
    if batch_size % max(1, n_dp) != 0:
        raise GraphError(
            f"batch_size={batch_size} is not divisible by the data-"
            f"parallel device count {n_dp} (mesh axes {dp_axes} of mesh "
            f"shape {dict(mesh.shape)}); batches shard over the data "
            "axes, so pick a batch size the data extent divides")
    from repro.core.embedding.planner import resolve_strategies
    from repro.launch.mesh import mesh_config_for
    from repro.models.recsys.model import wide_tables
    groups = [("emb", cfg.tables)]
    if cfg.model in ("wdl", "deepfm") or \
            (cfg.model == "graph" and cfg.wide_branch):
        groups.append(("wide", wide_tables(cfg)))
    for g in cfg.extra_groups:
        groups.append((g.name, g.tables))
    mc = mesh_config_for(mesh)
    for gname, tabs in groups:
        resolved = resolve_strategies(tabs, mc, batch_size)
        loc = [t for t in resolved if t.strategy == "localized"]
        if loc and len(loc) % n_dev != 0:
            raise GraphError(
                f"embedding group {gname!r}: {len(loc)} localized "
                f"table(s) {[t.name for t in loc]} cannot spread evenly "
                f"over {n_dev} devices; localized placement needs the "
                "table count divisible by the device count")


class Model:
    """A declarative model graph; ``compile()`` lowers it, everything
    else (fit / predict / save / load / deploy) drives the lowered
    stack."""

    def __init__(self, solver: Optional[Solver] = None,
                 reader: Optional[DataReaderParams] = None, *,
                 name: str = "model", mesh=None):
        self.solver = solver or Solver()
        self.reader = reader
        self.name = name
        self._mesh_override = mesh
        self._input: Optional[Input] = None
        self._embeddings: List[SparseEmbedding] = []
        self._dense_layers: List[DenseLayer] = []
        self.cfg: Optional[RecsysConfig] = None
        self.mesh = None
        self._model = None            # lowered RecsysModel
        self._apply_jit = None
        self._tcfg: Optional[TrainConfig] = None
        self._params = None
        self._opt_state = None
        self._trainer = None
        self._online = None           # OnlineTrainer after an ETC fit()
        self.stragglers = 0

    # -- graph construction ---------------------------------------------------

    def add(self, layer) -> "Model":
        if isinstance(layer, Input):
            if self._input is not None:
                raise GraphError("the graph already has an Input layer")
            self._input = layer
        elif isinstance(layer, SparseEmbedding):
            self._embeddings.append(layer)
        elif isinstance(layer, DenseLayer):
            self._dense_layers.append(layer)
        else:
            raise GraphError(
                f"model.add() takes Input, SparseEmbedding or "
                f"DenseLayer, got {type(layer).__name__}")
        return self

    def to_recsys_config(self) -> RecsysConfig:
        """The lowering pass (pure — no devices touched)."""
        return lower_graph(self.name, self._input, self._embeddings,
                           self._dense_layers)

    # -- compile ---------------------------------------------------------------

    def compile(self, *, mesh=None) -> "Model":
        from repro.models.recsys.model import RecsysModel
        self.cfg = self.to_recsys_config()
        if self.reader is not None and \
                self.reader.num_dense_features != self._input.dense_dim:
            raise GraphError(
                f"reader num_dense_features="
                f"{self.reader.num_dense_features} != Input dense_dim="
                f"{self._input.dense_dim}")
        self._tcfg = self.solver.to_train_config()
        self.batch_size = self.solver.batch_size
        self.mesh = mesh or self._mesh_override \
            or _auto_mesh(self.solver.mesh_shape)
        _validate_mesh_fit(self.cfg, self.mesh, self.batch_size)
        with self.mesh:
            self._model = RecsysModel(
                self.cfg, self.mesh, global_batch=self.batch_size,
                comm=self.solver.comm,
                a2a_threshold=self.solver.a2a_threshold)
        self._apply_jit = None        # one jitted forward, built lazily
        return self

    @property
    def model(self):
        """The lowered RecsysModel (compile() first)."""
        return self._model

    @property
    def params(self):
        return self._params

    def _require_compiled(self):
        if self._model is None:
            self.compile()

    # -- train ------------------------------------------------------------------

    def _reader_data_fn(self) -> Callable[[int], Dict]:
        r = self.reader or DataReaderParams(
            num_dense_features=self.cfg.num_dense_features)
        if r.source == "synthetic":
            from repro.data.synthetic import SyntheticCTR
            return SyntheticCTR(self.cfg, self.batch_size, seed=r.seed,
                                zipf_a=r.zipf_a).batch
        from repro.data import criteo
        if r.path is None:
            raise GraphError("DataReaderParams(source='criteo') needs "
                             "a path")
        # seekable batch(step): criteo runs get the same deterministic
        # failure-replay contract as the synthetic reader — the trainer
        # can restore mid-epoch and replay the exact batches
        return criteo.CriteoReader(r.path, self.cfg, self.batch_size).batch

    def fit(self, data_fn: Optional[Callable[[int], Dict]] = None,
            steps: int = 100, *, ckpt_dir: Optional[str] = None,
            log_every: int = 0, seed: Optional[int] = None,
            failure_injector: Optional[Callable[[int], None]] = None
            ) -> List[Dict]:
        """Train; ``data_fn(step) -> {"dense", "cat", "label"}`` host
        batches (defaults to the reader's source). Resumes from a newer
        checkpoint in ``ckpt_dir`` if present, else from weights already
        held (e.g. after :meth:`load`)."""
        self._require_compiled()
        if data_fn is None:
            data_fn = self._reader_data_fn()
        if self.solver.etc is not None:
            return self._fit_etc(data_fn, steps, ckpt_dir=ckpt_dir,
                                 log_every=log_every, seed=seed,
                                 failure_injector=failure_injector)
        from repro.train.trainer import Trainer
        with self.mesh:
            self._trainer = Trainer(
                self._model, self._tcfg, self.mesh, data_fn,
                ckpt_dir=ckpt_dir,
                ckpt_interval=self.solver.ckpt_interval,
                mode=self.solver.mode)
            if failure_injector is not None:
                self._trainer.failure_injector = failure_injector
            init = (self._params, self._opt_state) \
                if self._params is not None else None
            out = self._trainer.train(
                steps, seed=self.solver.seed if seed is None else seed,
                log_every=log_every, initial_state=init)
        self._params = out["params"]
        self._opt_state = out["opt_state"]
        self.stragglers = out["stragglers"]
        return out["history"]

    def _fit_etc(self, data_fn, steps, *, ckpt_dir, log_every, seed,
                 failure_injector, publisher=None) -> List[Dict]:
        """``fit()`` through the Embedding Training Cache (Solver.etc):
        keyset-staged passes over a fixed-capacity device cache, the
        parameter server as the durable tier, and — when ``publisher``
        is attached — one versioned online update per pass boundary.
        After training the PS contents are imported back into
        ``params``, so predict/save/deploy see a normal model."""
        if ckpt_dir is not None:
            raise GraphError(
                "ETC-staged fit() does not take ckpt_dir: durability "
                "goes through the parameter server — use "
                "ETCParams(ps='cached', ps_root=...) instead")
        if failure_injector is not None:
            raise GraphError(
                "ETC-staged fit() does not support failure_injector")
        from repro.online.trainer import OnlineTrainer
        with self.mesh:
            ot = OnlineTrainer(
                self, self.solver.etc, publisher=publisher,
                seed=self.solver.seed if seed is None else seed)
            history = ot.fit(data_fn, steps, log_every=log_every)
            self._params = ot.export_params()
        self._opt_state = None
        self._trainer = None
        self._online = ot
        return history

    # -- inference ----------------------------------------------------------------

    def predict(self, batch: Dict) -> np.ndarray:
        if self._params is None:
            raise RuntimeError("fit() or load() before predict()")
        if self._apply_jit is None:
            self._apply_jit = jax.jit(self._model.apply)
        with self.mesh:
            logits = self._apply_jit(
                self._params,
                {k: jnp.asarray(v) for k, v in batch.items()
                 if k in ("dense", "cat")})
        return np.asarray(jax.nn.sigmoid(logits))

    # -- introspection ------------------------------------------------------------

    def summary(self) -> str:
        cfg = self.to_recsys_config()
        lines = [f'Model "{self.name}" -> {cfg.model} '
                 f'({cfg.num_tables} tables, '
                 f'{cfg.total_embedding_params / 1e6:.2f}M embedding '
                 f'params)']
        i = self._input
        lines.append(f"  Input              {i.dense_name}[{i.dense_dim}]"
                     f" {i.sparse_name} {i.label_name}")
        for e in self._embeddings:
            hot = e.hotness if isinstance(e.hotness, int) \
                else f"{min(e.hotness)}..{max(e.hotness)}"
            lines.append(
                f"  SparseEmbedding    {e.bottom_name} -> {e.top_name}"
                f"  T={len(e.vocab_sizes)} D={e.dim} hot={hot} "
                f"combiner={e.combiner} strategy={e.strategy}")
        for l in self._dense_layers:
            extra = ""
            if l.type == "mlp":
                extra = f"  units={l.units}"
            elif l.type == "cross":
                extra = f"  num_layers={l.num_layers}"
            lines.append(
                f"  DenseLayer {l.type:<15} "
                f"{list(l.bottom_names)} -> {l.top}{extra}")
        out = "\n".join(lines)
        print(out)
        return out

    # -- JSON round-trip ------------------------------------------------------------

    def graph_dict(self) -> Dict:
        layers: List[Dict] = []
        if self._input is not None:
            layers.append({"kind": "input",
                           **dataclasses.asdict(self._input)})
        for e in self._embeddings:
            layers.append({"kind": "sparse_embedding",
                           **dataclasses.asdict(e)})
        for l in self._dense_layers:
            layers.append({"kind": "dense", **dataclasses.asdict(l)})
        return {
            "format": GRAPH_FORMAT,
            "name": self.name,
            "solver": dataclasses.asdict(self.solver),
            "reader": dataclasses.asdict(self.reader)
            if self.reader is not None else None,
            "layers": layers,
            "config_hash": recsys_config_hash(self.to_recsys_config()),
        }

    def graph_to_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.graph_dict(), f, indent=1)
        return path

    @classmethod
    def from_json(cls, path: str, *, mesh=None) -> "Model":
        with open(path) as f:
            d = json.load(f)
        if d.get("format") != GRAPH_FORMAT:
            raise GraphError(
                f"{path}: unknown graph format {d.get('format')!r}")
        m = cls(Solver(**d["solver"]),
                DataReaderParams(**d["reader"])
                if d.get("reader") else None,
                name=d["name"], mesh=mesh)
        kinds = {"input": Input, "sparse_embedding": SparseEmbedding,
                 "dense": DenseLayer}
        for ld in d["layers"]:
            ld = dict(ld)
            kind = ld.pop("kind")
            if kind not in kinds:
                raise GraphError(f"{path}: unknown layer kind {kind!r}")
            m.add(kinds[kind](**ld))
        got = recsys_config_hash(m.to_recsys_config())
        if d.get("config_hash") and got != d["config_hash"]:
            raise GraphError(
                f"{path}: graph lowers to config hash {got} but the "
                f"file claims {d['config_hash']} — the file was edited "
                "or written by an incompatible version")
        return m

    # -- persistence -----------------------------------------------------------------

    def _export_params(self, params):
        from repro.models.recsys.model import export_logical_params
        return export_logical_params(self._model, params)

    def _import_params(self, params):
        from repro.models.recsys.model import import_logical_params
        return import_logical_params(self._model, params)

    def save(self, directory: str, step: int = 0) -> str:
        """Write the graph (graph.json) + a logical-layout checkpoint —
        everything :meth:`load` needs to reconstruct the model."""
        if self._params is None:
            raise RuntimeError("nothing to save: fit() or load() first")
        from repro.train import checkpoint as ck
        os.makedirs(directory, exist_ok=True)
        self.graph_to_json(os.path.join(directory, "graph.json"))
        with self.mesh:
            tree = {"params": self._export_params(self._params)}
        ck.save(directory, step, tree)
        return directory

    @classmethod
    def load(cls, directory: str, *, mesh=None) -> "Model":
        """Rebuild a model from :meth:`save` output alone: graph JSON +
        newest checkpoint. ``predict()`` works immediately; ``fit()``
        continues from the loaded weights."""
        from repro.train import checkpoint as ck
        m = cls.from_json(os.path.join(directory, "graph.json"),
                          mesh=mesh)
        m.compile()
        step = ck.latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint under {directory}")
        flat, _ = ck.load(directory, step)
        with m.mesh:
            dummy = jax.eval_shape(
                lambda: m._model.init(jax.random.PRNGKey(0)))
            template = {"params": jax.eval_shape(m._export_params,
                                                 dummy)}
            tree = ck.unflatten_like(template, flat)
            m._params = m._import_params(tree["params"])
        return m

    # -- deployment -------------------------------------------------------------------

    def dense_params(self) -> Dict:
        from repro.train.train_step import is_sparse_key
        return {k: v for k, v in self._params.items()
                if not is_sparse_key(k)}

    def _write_bundle_member(self, pdb, bundle_dir: str, sub: str, *,
                             cache_capacity: int, cache_shards: int,
                             refresh_budget: int, max_batch: int,
                             payload_dtype: str = "f32") -> HPSConfig:
        """Export THIS model into a deployment bundle: tables into the
        (possibly shared) PDB, graph.json + dense.npz under
        ``bundle_dir/sub``, returning the relocatable HPSConfig whose
        paths are relative to ``bundle_dir``."""
        from repro.serve.server import deploy_from_training
        from repro.train import checkpoint as ck
        out_dir = os.path.join(bundle_dir, sub) if sub else bundle_dir
        os.makedirs(out_dir, exist_ok=True)
        with self.mesh:
            deploy_from_training(self._model, self._params, pdb,
                                 self.name)
        self.graph_to_json(os.path.join(out_dir, "graph.json"))
        np.savez(os.path.join(out_dir, "dense.npz"),
                 **ck.flatten_tree(self.dense_params()))
        rel = (lambda p: f"{sub}/{p}" if sub else p)
        return HPSConfig(
            model=self.name, pdb_root="pdb", graph_path=rel("graph.json"),
            dense_weights_path=rel("dense.npz"), tables=self.cfg.tables,
            wide=self._model.wide is not None,
            cache_capacity=cache_capacity, cache_shards=cache_shards,
            refresh_budget=refresh_budget, max_batch=max_batch,
            payload_dtype=payload_dtype,
            config_hash=recsys_config_hash(self.cfg))

    def _build_server(self, pdb, hcfg: HPSConfig, dense: Dict, *,
                      vdb=None, bus=None):
        """Stand up the HPS(+wide) + InferenceServer for this model over
        already-populated storage — the ONE place the serving stack is
        wired, shared by in-process ``deploy()``/``deploy_ensemble()``
        and the config-driven ``launch.serve`` rebuild (``dense`` is the
        dense param tree: live for the former, reloaded from the
        bundle's npz for the latter)."""
        from repro.core.hps.hps import HPS
        from repro.models.recsys.model import wide_tables
        from repro.serve.server import InferenceServer
        hps = HPS(self.name, self.cfg.tables, pdb, vdb=vdb, bus=bus,
                  cache_capacity=hcfg.cache_capacity,
                  cache_shards=hcfg.cache_shards,
                  payload_dtype=hcfg.payload_dtype)
        wide_hps = None
        if hcfg.wide:
            # the wide branch shares the bus (its *_wide topics mark its
            # own L1 dirty), the VDB namespace and the striping config —
            # otherwise online updates never reach the wide L1
            wide_hps = HPS(self.name, wide_tables(self.cfg), pdb,
                           vdb=vdb, bus=bus,
                           cache_capacity=hcfg.cache_capacity,
                           cache_shards=hcfg.cache_shards,
                           payload_dtype=hcfg.payload_dtype)
        # one HPS per extra N-group collection — its tables are derived
        # from the lowered config, so the ps.json schema is unchanged
        extra_hps = {
            g.name: HPS(self.name, g.tables, pdb, vdb=vdb, bus=bus,
                        cache_capacity=hcfg.cache_capacity,
                        cache_shards=hcfg.cache_shards,
                        payload_dtype=hcfg.payload_dtype)
            for g in self.cfg.extra_groups}
        return InferenceServer(self._model, dense, hps,
                               wide_hps=wide_hps,
                               extra_hps=extra_hps or None,
                               max_batch=hcfg.max_batch,
                               refresh_budget=hcfg.refresh_budget)

    def deploy(self, directory: str, *, cache_capacity: int = 4096,
               cache_shards: int = 1, refresh_budget: int = 512,
               max_batch: int = 1024, payload_dtype: str = "f32",
               vdb=None, bus=None):
        """Write the serving bundle and return a ready InferenceServer.

        The bundle — ``pdb/`` (every table, wide twins included),
        ``graph.json``, ``dense.npz``, ``ps.json`` — is all
        ``launch/serve.py`` needs: the same server can be reconstructed
        later with no Python object from this process. To serve SEVERAL
        models from one bundle/storage backend, see
        :func:`deploy_ensemble`.

        ``payload_dtype`` sets the L1 storage precision and persists in
        ps.json, so a config-driven rebuild serves the exact same mode:

        * ``"f32"`` (default) — bit-exact with the uncompressed store.
        * ``"f16"`` — half the HBM bytes per resident row; rows downcast
          on insert/refresh and widen to f32 inside the gather.
        * ``"int8"`` — ~4x fewer payload bytes (plus one f32 scale per
          row): rows are per-row absmax-quantized on insert/refresh and
          dequantized INSIDE the fused Pallas gather kernel, so the
          pooled ``[B, T, D]`` output stays f32 and a single jitted
          dispatch. At a fixed HBM budget that is 2-4x more resident hot
          rows — a direct L1 hit-rate (and therefore qps) lever.

        The PDB/VDB always hold full-precision rows; only the L1 payload
        is compressed, and dirty-row refreshes requantize from the
        full-precision lower levels (never from their own rounded rows).
        """
        if self._params is None:
            raise RuntimeError("fit() or load() before deploy()")
        from repro.core.hps.persistent_db import PersistentDB
        os.makedirs(directory, exist_ok=True)
        pdb = PersistentDB(os.path.join(directory, "pdb"))
        hcfg = self._write_bundle_member(
            pdb, directory, "", cache_capacity=cache_capacity,
            cache_shards=cache_shards, refresh_budget=refresh_budget,
            max_batch=max_batch, payload_dtype=payload_dtype)
        with open(os.path.join(directory, "ps.json"), "w") as f:
            json.dump(hps_config_to_dict(hcfg), f, indent=1)
        return self._build_server(pdb, hcfg, self.dense_params(),
                                  vdb=vdb, bus=bus)


# ---------------------------------------------------------------------------
# Ensemble deployment: several models, one storage backend
# ---------------------------------------------------------------------------

def _hotness_demand(tables) -> int:
    """A model's L1 working-set proxy from its table hotness stats:
    ids per sample x expected hot rows (the ``hot_fraction`` share of
    each vocab the planner already treats as the hot set)."""
    return max(1, sum(
        t.hotness * max(1, min(t.vocab_size,
                               round(t.vocab_size * t.hot_fraction)))
        for t in tables))


def hotness_cache_capacities(models: Sequence["Model"],
                             budget: int) -> Dict[str, int]:
    """Split one total L1 row ``budget`` across ensemble members in
    proportion to their table-hotness working sets (each model gets at
    least 64 rows so a cold member still serves)."""
    demand = {m.name: _hotness_demand(m.cfg.all_tables) for m in models}
    total = sum(demand.values())
    return {name: max(64, int(round(budget * d / total)))
            for name, d in demand.items()}


def deploy_ensemble(models: Sequence[Model], directory: str, *,
                    cache_capacity: Union[int, Dict[str, int],
                                          None] = None,
                    cache_budget: Optional[int] = None,
                    cache_shards: int = 1,
                    refresh_budget: int = 512, max_batch: int = 1024,
                    payload_dtype: str = "f32",
                    rebalance_interval_s: Optional[float] = None,
                    vdb=None, bus=None):
    """Write ONE multi-model serving bundle and return a ready
    :class:`~repro.serve.server.MultiModelServer`.

    All member models' tables land in a single shared ``pdb/`` (the PDB
    namespaces tables per model on disk) and the in-process server
    shares one VolatileDB and one message bus across models — the
    ensemble deployment unit of the GPU-specialized inference parameter
    server (arXiv 2210.08804): one parameter-server process, several
    models, per-model L1 caches. The bundle's ``ps.json`` holds one
    :class:`EnsembleConfig` (several HPSConfigs, shared ``pdb_root``)
    and ``launch/serve.py::build_server_from_config`` reconstructs the
    whole multi-model server from it, bit-exact with per-model
    in-process servers.

    Per-model L1 sizing: by default the total row budget
    (``cache_budget``, default ``4096 * len(models)``) is split across
    members in proportion to their table-hotness working sets
    (:func:`hotness_cache_capacities`) instead of handing every model
    one global knob. Explicit overrides still work: pass
    ``cache_capacity=<int>`` for a uniform per-model capacity, or a
    ``{model_name: rows}`` dict to pin specific members (unpinned ones
    keep their hotness share).

    ``rebalance_interval_s`` (opt-in, default off) re-splits that shared
    row budget periodically from *observed* per-model L1 miss pressure
    instead of the static declared hotness: the serving loop feeds the
    :class:`~repro.serve.server.MultiModelServer` rebalancer, which
    resizes member caches (hottest rows retained) at most once per
    interval. Leave it ``None`` for latency-critical serving — a resize
    recompiles the pooled gather for the new payload shape.

    ``payload_dtype`` applies to every member's L1 (see
    :meth:`Model.deploy` for the precision modes).
    """
    from repro.core.hps.message_bus import MessageBus
    from repro.core.hps.persistent_db import PersistentDB
    from repro.core.hps.volatile_db import VolatileDB
    from repro.serve.server import MultiModelServer
    if not models:
        raise GraphError("deploy_ensemble needs at least one model")
    names = [m.name for m in models]
    if len(set(names)) != len(names):
        raise GraphError(f"ensemble model names must be unique: {names}")
    for m in models:
        if m._params is None:
            raise RuntimeError(
                f"model {m.name!r}: fit() or load() before deploy")
    for m in models:
        m._require_compiled()
    budget = cache_budget if cache_budget is not None \
        else 4096 * len(models)
    capacities = hotness_cache_capacities(models, budget)
    if isinstance(cache_capacity, int):
        capacities = {m.name: cache_capacity for m in models}
    elif isinstance(cache_capacity, dict):
        unknown = set(cache_capacity) - {m.name for m in models}
        if unknown:
            raise GraphError(
                f"cache_capacity overrides for unknown models: "
                f"{sorted(unknown)}")
        capacities.update(cache_capacity)
    os.makedirs(directory, exist_ok=True)
    pdb = PersistentDB(os.path.join(directory, "pdb"))   # shared L3
    vdb = vdb if vdb is not None else VolatileDB()       # shared L2
    bus = bus if bus is not None else MessageBus()       # shared bus
    hcfgs = []
    servers = {}
    for m in models:
        hcfg = m._write_bundle_member(
            pdb, directory, m.name, cache_capacity=capacities[m.name],
            cache_shards=cache_shards, refresh_budget=refresh_budget,
            max_batch=max_batch, payload_dtype=payload_dtype)
        hcfgs.append(hcfg)
        servers[m.name] = m._build_server(pdb, hcfg, m.dense_params(),
                                          vdb=vdb, bus=bus)
    ens = EnsembleConfig(models=tuple(hcfgs))
    with open(os.path.join(directory, "ps.json"), "w") as f:
        json.dump(ensemble_config_to_dict(ens), f, indent=1)
    return MultiModelServer(servers, vdb=vdb, pdb=pdb, bus=bus,
                            cache_budget=budget,
                            rebalance_interval_s=rebalance_interval_s)
